"""Property tests (hypothesis) for the proximal operators and step rules —
the low-level invariants Algorithm 1's convergence proof leans on.
"""
import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core.prox import group_soft_threshold, soft_threshold
from repro.core.stepsize import gamma_schedule

S = settings(max_examples=25, deadline=None)

floats = st.floats(-100, 100, allow_nan=False)
pos = st.floats(0.01, 50, allow_nan=False)


@S
@given(st.lists(floats, min_size=1, max_size=32), pos)
def test_soft_threshold_is_prox_of_l1(vs, t):
    """z = soft(v,t) minimizes ½(z−v)² + t|z| — check first-order optimality
    and that it beats nearby points."""
    v = jnp.asarray(vs, jnp.float32)
    z = soft_threshold(v, t)
    obj = lambda u: 0.5 * (u - v) ** 2 + t * jnp.abs(u)
    f_z = obj(z)
    for delta in (1e-2, -1e-2, 0.1, -0.1):
        tol = 1e-5 * (1.0 + jnp.abs(f_z))      # fp32-relative
        assert bool(jnp.all(f_z <= obj(z + delta) + tol))


@S
@given(st.lists(floats, min_size=1, max_size=32), pos)
def test_soft_threshold_shrinks(vs, t):
    v = jnp.asarray(vs, jnp.float32)
    z = soft_threshold(v, t)
    assert bool(jnp.all(jnp.abs(z) <= jnp.abs(v) + 1e-6))
    assert bool(jnp.all(jnp.sign(z) * jnp.sign(v) >= 0))       # no sign flip
    # exact-zero region: |v| ≤ t ⇒ z = 0
    assert bool(jnp.all(jnp.where(jnp.abs(v) <= t, z == 0, True)))


@S
@given(st.lists(floats, min_size=2, max_size=16), pos)
def test_group_soft_threshold_norm(vs, t):
    """Block shrink: ‖z‖ = max(0, ‖v‖−t) and direction preserved."""
    v = jnp.asarray(vs, jnp.float32)[None, :]
    z = group_soft_threshold(v, t)
    nv = float(jnp.linalg.norm(v))
    nz = float(jnp.linalg.norm(z))
    assert abs(nz - max(0.0, nv - t)) < 1e-3 * max(1.0, nv)
    if nv > t * (1 + 1e-3) and t > 0 and nv > 1e-3:
        # strictly outside the shrinkage boundary: direction preserved
        cos = float(jnp.vdot(v, z)) / max(nv * nz, 1e-30)
        assert cos > 0.999


@S
@given(st.floats(0.1, 1.0), st.floats(1e-6, 0.5))
def test_gamma_rule_theorem1_conditions(g0, theta):
    """Eq. (4): γᵏ ∈ (0,1], strictly decreasing, not summable too fast.

    (Σγ = ∞ and Σγ² < ∞ hold asymptotically since γᵏ ~ 1/(θk); here we
    check monotonicity, positivity and the 1/(θk) envelope.)
    """
    g = gamma_schedule(g0, theta, 200)
    gn = np.asarray(g)
    assert (gn > 0).all() and (gn <= 1.0).all()
    assert (np.diff(gn) < 0).all()
    k = np.arange(1, 201)
    assert (gn <= 1.0 / (theta * k) + 1e-6).all()   # γᵏ ≤ 1/(θk) envelope


def test_nesterov_certificate():
    """The planted instance must satisfy its own optimality certificate."""
    from repro.problems.lasso import nesterov_instance
    p = nesterov_instance(m=60, n=300, nnz_frac=0.1, c=1.0, seed=3)
    # V(x*) == V* and stationarity ≈ 0 at x*
    assert abs(float(p.v(p.x_star)) - p.v_star) < 1e-3 * p.v_star
    assert float(p.stationarity(p.x_star, tau=1.0)) < 1e-3
    # subgradient condition off-support: |∇ᵢF| ≤ c
    g = np.asarray(p.grad_f(p.x_star))
    off = np.asarray(p.x_star) == 0
    assert (np.abs(g[off]) <= 1.0 + 1e-4).all()
