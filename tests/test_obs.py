"""``repro.obs`` — unified tracing + cost ledger + live ops view.

Pins the PR's three contracts:

* **determinism** — tracing OFF is bitwise-identical to an
  uninstrumented run (host-side spans never touch device programs);
  tracing ON under an injected clock is byte-identical run to run
  (JSONL export compared verbatim);
* **conservation** — every ledger producer satisfies
  ``row_iters == live_iters + padding_iters + freeze_iters`` and prices
  flops in the one shared matvec currency;
* **schema stability** — span/instant records, ledger dicts and the
  telemetry snapshot keep their key sets (dashboards and
  ``BENCH_obs.json`` parse them blind).
"""
import json
import warnings

import numpy as np
import pytest

from repro.obs import CostLedger, LEDGER_KEYS, Tracer, get_tracer, tracing
from repro.obs import trace as obs
from repro.obs.dashboard import render_requests, render_snapshot, sparkline
from repro.obs.trace import INSTANT_KEYS, SPAN_KEYS
from repro.serve.metrics import ServeTelemetry, percentile


class FakeClock:
    """Deterministic injectable clock: 0.0, 0.5, 1.0, ..."""

    def __init__(self, step: float = 0.5):
        self.t = 0.0
        self.step = step

    def __call__(self) -> float:
        t, self.t = self.t, self.t + self.step
        return t


@pytest.fixture(autouse=True)
def _silence_legacy_warnings():
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", FutureWarning)
        yield


def _lasso(seed: int):
    from repro.problems.lasso import nesterov_instance
    return nesterov_instance(m=24, n=64, nnz_frac=0.1, c=1.0, seed=seed)


# ------------------------------------------------------------------ #
# Tracer                                                             #
# ------------------------------------------------------------------ #
def test_tracer_records_nesting_and_ids():
    t = Tracer(clock=FakeClock())
    with t.span("outer", cat="a", k=1):
        t.instant("mark", cat="a", v=2)
        with t.span("inner", cat="b"):
            pass
    ev = t.events()
    assert [e["name"] for e in ev] == ["outer", "mark", "inner"]
    assert [e["id"] for e in ev] == [0, 1, 2]
    outer, mark, inner = ev
    assert outer["parent"] is None
    assert mark["parent"] == 0 and inner["parent"] == 0
    assert outer["ph"] == "X" and mark["ph"] == "i"
    # FakeClock ticks: outer opens at 0.0, mark at 0.5, inner 1.0–1.5,
    # outer closes at 2.0
    assert (outer["t0"], inner["t0"], inner["t1"], outer["t1"]) == \
        (0.0, 1.0, 1.5, 2.0)
    assert outer["args"] == {"k": 1} and mark["args"] == {"v": 2}


def test_trace_schema_stability():
    t = Tracer(clock=FakeClock())
    with t.span("s"):
        t.instant("i")
    span_rec, inst_rec = t.events()
    assert tuple(span_rec) == SPAN_KEYS
    assert tuple(inst_rec) == INSTANT_KEYS


def test_tracer_exports_round_trip(tmp_path):
    t = Tracer(clock=FakeClock())
    with t.span("work", cat="x", n=3):
        t.instant("tick", cat="x")
    jsonl = t.to_jsonl(tmp_path / "trace.jsonl")
    assert (tmp_path / "trace.jsonl").read_text() == jsonl
    parsed = [json.loads(line) for line in jsonl.splitlines()]
    assert parsed == t.events()

    doc = t.to_chrome(tmp_path / "trace.json")
    loaded = json.loads((tmp_path / "trace.json").read_text())
    assert loaded["traceEvents"] == json.loads(json.dumps(
        doc["traceEvents"]))
    phases = {e["ph"] for e in doc["traceEvents"]}
    assert phases == {"X", "i"}
    x = next(e for e in doc["traceEvents"] if e["ph"] == "X")
    # µs timestamps, complete-event duration, pid/tid present: the
    # fields Perfetto's trace-event importer requires
    assert x["ts"] == 0.0 and x["dur"] == pytest.approx(1.0e6)
    assert {"pid", "tid", "name", "cat"} <= set(x)


def test_tracer_clear_resets_ids():
    t = Tracer(clock=FakeClock())
    with t.span("a"):
        pass
    t.clear()
    with t.span("b"):
        pass
    assert t.events()[0]["id"] == 0


def test_module_helpers_default_to_noop():
    assert get_tracer() is None
    # no tracer: span() hands back a shared null context, instant()
    # returns without recording — the single-global-read fast path
    cm = obs.span("anything", cat="x", k=1)
    assert cm is obs._NULL_CM
    with cm:
        obs.instant("nothing")
    assert get_tracer() is None


def test_tracing_scope_restores_previous():
    t1, t2 = Tracer(clock=FakeClock()), Tracer(clock=FakeClock())
    with tracing(t1):
        assert get_tracer() is t1
        with tracing(t2):
            assert get_tracer() is t2
            obs.instant("inner")
        assert get_tracer() is t1
        obs.instant("outer")
    assert get_tracer() is None
    assert [e["name"] for e in t1.events()] == ["outer"]
    assert [e["name"] for e in t2.events()] == ["inner"]


# ------------------------------------------------------------------ #
# CostLedger                                                         #
# ------------------------------------------------------------------ #
def test_ledger_math_and_conservation():
    led = CostLedger()
    led.add(row_iters=100, live_iters=60, padding_iters=30,
            freeze_iters=10, device_flops=1000, compiles=2)
    assert led.conserved()
    assert led.waste_iters == 40
    assert led.utilization == pytest.approx(0.6)

    other = CostLedger(row_iters=10, live_iters=10)
    total = led + other
    assert total.row_iters == 110 and total.live_iters == 70
    assert led.row_iters == 100                 # __add__ is pure
    led.merge(other)                            # merge is in place
    assert led.row_iters == 110

    cp = led.copy()
    cp.add(row_iters=1)
    assert cp.row_iters == led.row_iters + 1


def test_ledger_rejects_unknown_keys_and_round_trips():
    led = CostLedger()
    with pytest.raises(KeyError, match="unknown ledger key"):
        led.add(flops=3)
    led.add(row_iters=5, live_iters=5)
    d = led.as_dict()
    assert tuple(k for k in d if k != "utilization") == LEDGER_KEYS
    assert CostLedger.from_dict(d).as_dict() == d
    # empty ledger: utilization degenerates to 1.0, still conserved
    assert CostLedger().utilization == 1.0 and CostLedger().conserved()


def test_telemetry_ledger_from_chunks_and_waves():
    tele = ServeTelemetry(clock=FakeClock())
    tele.record_chunk(live=3, capacity=4, chunk_iters=10, wall_s=0.1,
                      flops=10 * 4 * 24 * 64)
    tele.record_wave(bucket=8, n_real=5, iters=[7, 7, 3, 2, 1],
                     wall_s=0.1, flops=8 * 7 * 24 * 64)
    led = tele.ledger()
    assert led.conserved()
    # chunk: row 40, live 30, remainder → padding (freeze inseparable)
    # wave:  row 56, live 20, padding 3·7=21, freeze 56−20−21=15
    assert led.row_iters == 40 + 56
    assert led.live_iters == 30 + 20
    assert led.padding_iters == 10 + 21
    assert led.freeze_iters == 15
    assert led.device_flops == (40 + 56) * 24 * 64
    snap = tele.snapshot()
    assert snap["ledger"]["row_iters"] == led.row_iters
    assert snap["wave"]["device_flops"] == 56 * 24 * 64
    assert snap["continuous"]["device_flops"] == 40 * 24 * 64


# ------------------------------------------------------------------ #
# ServeTelemetry edge cases (snapshot under partial lifecycles)      #
# ------------------------------------------------------------------ #
def test_percentile_empty_sample_is_none():
    assert percentile([], 50) is None
    assert percentile([], 99) is None
    assert percentile([1.0], 50) == 1.0


def test_snapshot_with_in_flight_requests():
    tele = ServeTelemetry(clock=FakeClock())
    for rid, fam in enumerate(("lasso", "lasso", "logreg")):
        tele.record_arrival(rid, fam, "continuous")
    tele.record_admit(0)
    tele.record_completion(0, iters=12, converged=True)
    tele.record_admit(1)                        # admitted, not completed
    snap = tele.snapshot()
    assert snap["requests"] == 3
    assert snap["completed"] == 1
    assert snap["in_flight"] == 2
    assert snap["iters_total"] == 12            # completed requests only
    # latency percentiles come from the one completed request; the
    # in-flight ones must not poison them with None
    assert snap["latency_p50"] is not None
    assert snap["latency_p99"] == snap["latency_p50"]


def test_snapshot_empty_telemetry_percentiles_are_none():
    snap = ServeTelemetry(clock=FakeClock()).snapshot()
    assert snap["requests"] == 0 and snap["in_flight"] == 0
    for key in ("latency_p50", "latency_p99", "latency_mean",
                "latency_max", "queue_wait_p50", "queue_wait_p99"):
        assert snap[key] is None
    assert "continuous" not in snap and "wave" not in snap


def test_snapshot_schema_stability():
    tele = ServeTelemetry(clock=FakeClock())
    tele.record_arrival(0, "lasso", "continuous")
    tele.record_admit(0)
    tele.record_completion(0, iters=5, converged=True)
    tele.record_chunk(live=1, capacity=2, chunk_iters=5, wall_s=0.1)
    tele.record_wave(bucket=2, n_real=1, iters=[5], wall_s=0.1)
    snap = tele.snapshot()
    assert set(snap) == {
        "schema",
        "requests", "completed", "in_flight", "converged", "iters_total",
        "latency_p50", "latency_p99", "latency_mean", "latency_max",
        "queue_wait_p50", "queue_wait_p99", "ledger", "compile_cache",
        "continuous", "wave"}
    assert set(snap["ledger"]) == set(LEDGER_KEYS) | {"utilization"}


def test_progress_sampling_is_opt_in():
    tele = ServeTelemetry(clock=FakeClock())
    tele.record_arrival(0, "lasso", "continuous")
    tele.record_progress(0, iters=5, stat=0.5)      # off: dropped
    assert tele.requests[0].samples == []
    tele.sample_progress = True
    tele.record_progress(0, iters=5, stat=0.5)
    tele.record_progress(999, iters=1, stat=0.1)    # unknown id: ignored
    # arrival consumed clock tick 0.0; the sample is stamped at 0.5
    assert tele.requests[0].samples == [(pytest.approx(0.5), 5, 0.5)]
    assert "samples" in tele.requests[0].as_dict()


# ------------------------------------------------------------------ #
# Determinism: tracing off is bitwise-identical, on is reproducible  #
# ------------------------------------------------------------------ #
def _run_continuous_batch(probs):
    from repro.client import BatchSpec, FlexaClient
    from repro.config.base import ServeConfig, SolverConfig
    with FlexaClient(backend="continuous",
                     solver=SolverConfig(tol=1e-7, max_iters=4000,
                                         tau_adapt=False),
                     serve=ServeConfig(slab_capacity=4,
                                       chunk_iters=50)) as c:
        return c.run(BatchSpec(problems=probs))


def test_tracing_off_bitwise_identity():
    """The tentpole determinism gate: an untraced run and a traced run
    execute the same device programs — solutions bitwise equal."""
    probs = [_lasso(s) for s in range(3)]
    base = _run_continuous_batch(probs)
    tr = Tracer(clock=FakeClock())
    with tracing(tr):
        traced = _run_continuous_batch(probs)
    assert get_tracer() is None
    np.testing.assert_array_equal(np.asarray(base.x),
                                  np.asarray(traced.x))
    np.testing.assert_array_equal(np.asarray(base.iters),
                                  np.asarray(traced.iters))
    # and the trace actually saw the run
    counts = tr.counts()
    assert counts.get("serve.chunk", 0) > 0
    assert counts.get("serve.admit", 0) == 3
    assert counts.get("serve.evict", 0) == 3


def test_traced_runs_identical_under_injected_clock():
    """Two traced runs of the same workload under the same injected
    clock export byte-identical JSONL (caches pre-warmed so the
    compile-event stream is steady-state)."""
    probs = [_lasso(s) for s in range(3)]
    _run_continuous_batch(probs)                # warm compile caches
    texts = []
    for _ in range(2):
        tr = Tracer(clock=FakeClock())
        with tracing(tr):
            _run_continuous_batch(probs)
        texts.append(tr.to_jsonl())
    assert texts[0] == texts[1]
    assert texts[0]                             # non-empty


def test_path_driver_accepts_injected_clock():
    from repro.path.driver import _solve_path
    prob = _lasso(0)
    base = _solve_path(prob, n_points=4, lam_min_ratio=0.1)
    clocked = _solve_path(prob, n_points=4, lam_min_ratio=0.1,
                          clock=FakeClock())
    np.testing.assert_array_equal(base.x, clocked.x)
    # 2 ticks of 0.5 exactly: t0 at 0.0, wall stamped at 0.5
    assert clocked.meta["wall_s"] == pytest.approx(0.5)
    assert clocked.ledger is not None and clocked.ledger.conserved()
    assert clocked.ledger.device_flops == clocked.device_flops


def test_path_batched_accepts_injected_clock():
    from repro.path.driver import _solve_path_batched
    probs = [_lasso(s) for s in range(2)]
    base = _solve_path_batched(probs, n_points=3, lam_min_ratio=0.1)
    clocked = _solve_path_batched(probs, n_points=3, lam_min_ratio=0.1,
                                  clock=FakeClock())
    for b, c in zip(base, clocked):
        np.testing.assert_array_equal(b.x, c.x)
        assert c.meta["wall_s"] == pytest.approx(0.5)
        assert c.ledger is not None and c.ledger.conserved()


# ------------------------------------------------------------------ #
# Client integration: ledgers + diagnostics                          #
# ------------------------------------------------------------------ #
def test_client_results_carry_conserved_ledgers():
    from repro.client import BatchSpec, FlexaClient, PathSpec, SoloSpec
    with FlexaClient() as c:
        solo = c.run(SoloSpec(_lasso(0)))
        m, n = 24, 64
        assert solo.ledger.conserved()
        assert solo.ledger.device_flops == solo.iters * m * n
        batch = c.run(BatchSpec(problems=[_lasso(s) for s in range(3)]))
        assert batch.ledger.conserved()
        assert batch.ledger.row_iters == \
            int(np.asarray(batch.iters).max()) * 3
        assert batch.ledger.live_iters == int(np.asarray(batch.iters).sum())
        path = c.run(PathSpec(_lasso(0), n_points=4, lam_min_ratio=0.1))
        assert path.ledger.conserved()
        assert path.ledger.device_flops == path.device_flops


def test_client_cv_ledger_not_overcounted():
    """Inline CV folds share ONE sweep-wide ledger; the CVResult ledger
    must equal it (plus any winner re-solve), not K copies of it."""
    from repro.client import CVSpec, FlexaClient
    with FlexaClient() as c:
        r = c.run(CVSpec(problems=[_lasso(s) for s in range(3)],
                         n_points=4, lam_min_ratio=0.1))
        assert r.ledger is not None
        assert r.ledger.as_dict() == r.folds[0].ledger.as_dict()


def test_client_diagnostics_continuous_with_sampling():
    from repro.client import BatchSpec, FlexaClient, TicketDiagnostics
    with FlexaClient(backend="continuous") as c:
        c.telemetry.sample_progress = True
        probs = [_lasso(s) for s in range(3)]
        ticket = c.submit(BatchSpec(problems=probs))
        d0 = c.diagnostics(ticket)              # in flight, pre-step
        assert isinstance(d0, TicketDiagnostics) and not d0.done
        c.result(ticket)
        d = c.diagnostics(ticket)
        assert d.done and d.kind == "batch" and d.backend == "continuous"
        assert len(d.requests) == 3
        for req in d.requests:
            assert req["completed"] is not None
            assert len(req["samples"]) >= 1     # sampling was on
        assert "queued" in c.stats()
        with pytest.raises(KeyError):
            c.diagnostics(999)


def test_client_diagnostics_inline_reports_requests():
    from repro.client import FlexaClient, SoloSpec
    with FlexaClient() as c:
        t = c.submit(SoloSpec(_lasso(0)))
        d = c.diagnostics(t)
        assert d.done and len(d.requests) == 1
        assert d.requests[0]["family"] == "lasso"
        assert d.requests[0]["completed"] is not None
        assert d.as_dict()["backend"] == "inline"


# ------------------------------------------------------------------ #
# Dashboard rendering (pure)                                         #
# ------------------------------------------------------------------ #
def test_sparkline_edges():
    assert sparkline([]) == ""
    assert sparkline([1.0, 1.0, 1.0]) == "▁▁▁"          # flat → floor
    s = sparkline(list(range(100)), width=16)
    assert len(s) == 16
    assert s[0] == "▁" and s[-1] == "█"                 # ends kept
    assert sparkline([0.0, None, 1.0]) == "▁█"          # Nones dropped


def test_render_snapshot_sections():
    tele = ServeTelemetry(clock=FakeClock())
    tele.record_arrival(0, "lasso", "continuous")
    tele.record_admit(0)
    tele.record_completion(0, iters=7, converged=True)
    tele.record_chunk(live=1, capacity=2, chunk_iters=7, wall_s=0.1,
                      flops=7 * 2 * 24 * 64)
    text = render_snapshot(tele.snapshot(), queue_depth=4, title="t")
    for token in ("requests", "queue     depth 4", "latency", "ledger",
                  "slab", "cache"):
        assert token in text
    # empty snapshot renders without crashing and without sections
    empty = render_snapshot({}, title="empty")
    assert "ledger" not in empty


def test_render_requests_sparklines():
    diag = {"ticket": 7, "requests": [
        {"req_id": 0, "family": "lasso", "iters": 42, "converged": True,
         "completed": 1.0,
         "samples": [(0.0, 10, 1.0), (0.5, 20, 0.1), (1.0, 42, 0.01)]},
        {"req_id": 1, "family": "lasso", "iters": 5, "converged": False,
         "completed": None, "samples": []},
    ]}
    text = render_requests([diag])
    assert "req[0]" in text and "done✓" in text
    assert "req[1]" in text and "running" in text
    assert render_requests([]).startswith("(no sampled requests")
