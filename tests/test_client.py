"""``repro.client`` — the one front door.

The acceptance matrix lives here: all four workload kinds (solo, batch,
path, CV) × all three backends (inline, wave, continuous) × ≥2 problem
families, each compared against the legacy entry point it replaces:

* inline results are **bitwise** equal to the legacy path (same code,
  same compiled program — the deterministic-config guarantee);
* serve backends agree within the stack's established 1e-5 tol-stopping
  envelope (fp32 reduction-order noise shifts stopping times, never
  answers — see repro/solvers/batched.py).

Plus the session behaviours (stream/step/pending, buffered waves,
backend capability errors, spec validation, ClientConfig composition)
and the coarse-to-fine CV continuation contract.
"""
import dataclasses
import warnings

import numpy as np
import pytest

from repro.client import (BatchSpec, CVSpec, FlexaClient, PathSpec,
                          SoloSpec, SpecError, UnknownBackendError,
                          UnsupportedWorkloadError, available_backends)
from repro.config.base import ClientConfig, ServeConfig, SolverConfig
from repro.problems.lasso import make_lasso, nesterov_instance
from repro.problems.logreg import random_logreg_instance

BACKENDS = ("inline", "wave", "continuous")
#: Fixed τ + tol-stopping at 1e-7: the configuration whose cross-driver
#: agreement the serve/path PRs measured at ≤1e-5 (3e-6 typical).
CFG = SolverConfig(tol=1e-7, max_iters=4000, tau_adapt=False)
SERVE = ServeConfig(max_batch=4, slab_capacity=4, chunk_iters=50)
SOLO_FAMILIES = ("lasso", "logreg")
PATH_FAMILIES = ("lasso", "group_lasso")
GRID = dict(n_points=5, lam_min_ratio=0.1)

ATOL = {"inline": 0.0, "wave": 1e-5, "continuous": 1e-5}


def client(backend: str) -> FlexaClient:
    return FlexaClient(backend=backend, solver=CFG, serve=SERVE)


def _instance(family: str, seed: int):
    if family == "lasso":
        return nesterov_instance(m=24, n=64, nnz_frac=0.1, c=1.0,
                                 seed=seed)
    if family == "group_lasso":
        return nesterov_instance(m=24, n=64, nnz_frac=0.1, c=1.0,
                                 seed=seed, block_size=4)
    return random_logreg_instance(m=24, n=48, nnz_frac=0.15, c=0.5,
                                  seed=seed)


def _assert_close(got, ref, backend: str):
    if ATOL[backend] == 0.0:
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    else:
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=ATOL[backend])


@pytest.fixture(autouse=True)
def _silence_legacy_warnings():
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", FutureWarning)
        yield


# ------------------------------------------------------------------ #
# The equivalence matrix                                             #
# ------------------------------------------------------------------ #
@pytest.fixture(scope="module")
def solo_refs():
    from repro.solvers.api import _solve
    return {f: _solve(_instance(f, 0), cfg=CFG) for f in SOLO_FAMILIES}


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("family", SOLO_FAMILIES)
def test_matrix_solo(backend, family, solo_refs):
    got = client(backend).run(SoloSpec(problem=_instance(family, 0)))
    assert got.backend == backend
    assert got.converged
    _assert_close(got.x, solo_refs[family].x, backend)


@pytest.fixture(scope="module")
def batch_refs():
    from repro.solvers.batched import _solve_batched
    return {f: _solve_batched([_instance(f, s) for s in range(3)],
                              cfg=CFG) for f in SOLO_FAMILIES}


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("family", SOLO_FAMILIES)
def test_matrix_batch(backend, family, batch_refs):
    probs = [_instance(family, s) for s in range(3)]
    got = client(backend).run(BatchSpec(problems=probs))
    assert len(got) == 3 and np.asarray(got.converged).all()
    _assert_close(got.x, batch_refs[family].x, backend)


@pytest.fixture(scope="module")
def path_refs():
    from repro.path.driver import _solve_path
    return {f: _solve_path(_instance(f, 0), cfg=CFG, **GRID)
            for f in PATH_FAMILIES}


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("family", PATH_FAMILIES)
def test_matrix_path(backend, family, path_refs):
    got = client(backend).run(PathSpec(problem=_instance(family, 0),
                                       **GRID))
    ref = path_refs[family]
    np.testing.assert_allclose(got.lambdas, ref.lambdas, rtol=1e-12)
    _assert_close(got.x, ref.x, backend)
    assert list(got.support) == list(ref.support)


def _cv_data(family: str):
    """K=3 folds + validation pairs sharing one shape signature."""
    rng = np.random.default_rng(7)
    n, bs = 48, (4 if family == "group_lasso" else 1)
    x_true = np.zeros(n, np.float32)
    x_true[rng.choice(n, 6, replace=False)] = 1.0
    folds, val = [], []
    for i in range(3):
        A = rng.standard_normal((24, n)).astype(np.float32)
        b = A @ x_true + 0.3 * rng.standard_normal(24).astype(np.float32)
        Av = rng.standard_normal((12, n)).astype(np.float32)
        bv = Av @ x_true + 0.3 * rng.standard_normal(12).astype(
            np.float32)
        folds.append(make_lasso(A, b, c=1.0, name=f"{family}_f{i}",
                                block_size=bs))
        val.append((Av, bv))
    return folds, val


@pytest.fixture(scope="module")
def cv_refs():
    """Legacy CV: lockstep fold sweep + manual mean-MSE selection."""
    from repro.path.driver import _solve_path_batched
    out = {}
    for f in PATH_FAMILIES:
        folds, val = _cv_data(f)
        paths = _solve_path_batched(folds, cfg=CFG, **GRID)
        P = paths[0].lambdas.shape[0]
        mse = np.array([[float(np.sum((Av @ paths[i].x[k] - bv) ** 2))
                         / Av.shape[0]
                         for k in range(P)]
                        for i, (Av, bv) in enumerate(val)])
        best = int(np.argmin(mse.mean(axis=0)))
        out[f] = {"paths": paths, "best": best,
                  "best_lambda": float(paths[0].lambdas[best])}
    return out


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("family", PATH_FAMILIES)
def test_matrix_cv(backend, family, cv_refs):
    folds, val = _cv_data(family)
    got = client(backend).run(CVSpec(problems=folds, validation=val,
                                     **GRID))
    ref = cv_refs[family]
    assert got.best_index == ref["best"]
    assert got.best_lambda == pytest.approx(ref["best_lambda"],
                                            rel=1e-12)
    for i, path in enumerate(ref["paths"]):
        _assert_close(got.folds[i].x, path.x, backend)
    _assert_close(got.x_best,
                  np.stack([p.x[ref["best"]] for p in ref["paths"]]),
                  backend)


@pytest.mark.parametrize("backend", ("wave", "continuous"))
def test_matrix_path_cold_respects_warm_flag(backend, path_refs):
    """PathSpec.warm/screen reach the serve path protocol too: a cold
    unscreened path through a serve backend matches the inline cold
    reference (it must NOT silently warm-start)."""
    from repro.path.driver import _solve_path

    cold_ref = _solve_path(_instance("lasso", 0), cfg=CFG, warm=False,
                           screen=False, **GRID)
    got = client(backend).run(PathSpec(problem=_instance("lasso", 0),
                                       warm=False, screen=False, **GRID))
    np.testing.assert_allclose(got.x, cold_ref.x, atol=1e-5)
    # ...and per-point iteration counts now follow the cold profile, not
    # the warm one (the warm reference differs from cold on this grid).
    warm_ref = path_refs["lasso"]
    assert list(got.iters) != list(warm_ref.iters) \
        or np.allclose(warm_ref.x, cold_ref.x, atol=1e-7)


# ------------------------------------------------------------------ #
# Determinism (bitwise under fixed seed)                             #
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("backend", BACKENDS)
def test_backend_is_bitwise_deterministic(backend):
    """Two fresh sessions, same spec/config/seed → identical bits (the
    per-request PRNG streams are keyed by request identity, never by
    time or slot)."""
    cfg = dataclasses.replace(CFG, selection="hybrid", sel_p=0.5, seed=3,
                              max_iters=2000)
    spec = BatchSpec(problems=[_instance("lasso", s) for s in range(3)])
    xs = [FlexaClient(backend=backend, solver=cfg, serve=SERVE).run(spec).x
          for _ in range(2)]
    np.testing.assert_array_equal(np.asarray(xs[0]), np.asarray(xs[1]))


# ------------------------------------------------------------------ #
# Coarse-to-fine CV continuation (the tol_coarse contract)           #
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("backend", BACKENDS)
def test_cv_tol_coarse_matches_full_accuracy_sweep(backend, cv_refs):
    """The satellite contract: a loose-tol sweep + full-tol winner
    re-solve selects the same λ and lands on the same winner solutions
    as the all-points-full-accuracy sweep — for strictly less sweep
    work."""
    folds, val = _cv_data("lasso")
    ref = cv_refs["lasso"]
    got = client(backend).run(CVSpec(problems=folds, validation=val,
                                     tol_coarse=1e-3, **GRID))
    assert got.best_index == ref["best"]
    assert got.meta["tol_coarse"] == 1e-3
    np.testing.assert_allclose(
        got.x_best,
        np.stack([p.x[ref["best"]] for p in ref["paths"]]), atol=1e-5)
    coarse_work = sum(int(f.iters.sum()) for f in got.folds)
    full_work = sum(int(p.iters.sum()) for p in ref["paths"])
    assert coarse_work < full_work


# ------------------------------------------------------------------ #
# Session behaviour                                                  #
# ------------------------------------------------------------------ #
def test_stream_yields_in_completion_order():
    c = client("continuous")
    tickets = [c.submit(SoloSpec(problem=_instance("lasso", s)))
               for s in range(3)]
    assert c.pending == 3
    seen = dict(c.stream())
    assert sorted(seen) == sorted(tickets)
    assert c.pending == 0
    for t in tickets:
        assert seen[t].converged

def test_wave_backend_buffers_then_batches_one_wave():
    c = client("wave")
    for s in range(3):
        c.submit(SoloSpec(problem=_instance("lasso", s)))
    assert c.pending == 3                  # nothing dispatched yet
    done = c.step()                        # ONE wave for all three
    assert len(done) == 3 and c.pending == 0
    stats = c.stats()
    assert stats["engines"][0]["requests"] == 3
    assert stats["engines"][0]["batches"] == 1


def test_inline_completes_at_submit():
    c = client("inline")
    t = c.submit(SoloSpec(problem=_instance("lasso", 0)))
    assert c.pending == 0
    assert c.result(t, wait=False) is not None


def test_run_result_and_drain_agree():
    c = client("continuous")
    t1 = c.submit(SoloSpec(problem=_instance("lasso", 0)))
    t2 = c.submit(SoloSpec(problem=_instance("lasso", 1)))
    out = c.drain()
    assert set(out) == {t1, t2}
    assert out[t1] is c.result(t1)


def test_solo_history_contract_inline():
    got = client("inline").run(SoloSpec(problem=_instance("lasso", 0),
                                        method="fista"))
    assert got.raw.method == "fista"
    assert len(got.history["V"]) == got.iters


# ------------------------------------------------------------------ #
# Capability + validation errors                                     #
# ------------------------------------------------------------------ #
def test_unknown_backend_rejected():
    with pytest.raises(UnknownBackendError, match="unknown backend"):
        FlexaClient(backend="quantum")
    assert set(available_backends()) >= {"inline", "wave", "continuous"}


@pytest.mark.parametrize("backend", ("wave", "continuous"))
def test_non_flexa_methods_are_inline_only(backend):
    with pytest.raises(UnsupportedWorkloadError, match="inline"):
        client(backend).submit(SoloSpec(problem=_instance("lasso", 0),
                                        method="fista"))


@pytest.mark.parametrize("backend", ("wave", "continuous"))
def test_record_history_is_inline_only(backend):
    with pytest.raises(UnsupportedWorkloadError, match="record_history"):
        client(backend).submit(BatchSpec(
            problems=[_instance("lasso", 0)], record_history=True))


@pytest.mark.parametrize("backend", ("wave", "continuous"))
def test_nonquadratic_paths_are_inline_only(backend):
    with pytest.raises(UnsupportedWorkloadError, match="inline"):
        client(backend).submit(PathSpec(problem=_instance("logreg", 0),
                                        **GRID))
    # ...while the inline backend runs them (logreg screening landed
    # with this PR).
    r = client("inline").run(PathSpec(problem=_instance("logreg", 0),
                                      n_points=4, lam_min_ratio=0.2))
    assert r.x.shape[0] == 4


def test_spec_validation_errors():
    c = client("inline")
    with pytest.raises(SpecError, match="at least one problem"):
        c.submit(BatchSpec(problems=[]))
    with pytest.raises(SpecError, match="must be a Problem"):
        c.submit(SoloSpec(problem=np.zeros((3, 3))))
    with pytest.raises(SpecError, match="unknown workload spec"):
        c.submit(object())
    folds, val = _cv_data("lasso")
    with pytest.raises(SpecError, match="align"):
        c.submit(CVSpec(problems=folds, validation=val[:1]))
    with pytest.raises(SpecError, match="scoring route"):
        c.submit(CVSpec(problems=folds, tol_coarse=1e-3))
    with pytest.raises(SpecError, match="mutually exclusive"):
        c.submit(CVSpec(problems=folds, validation=val,
                        tol_coarse=1e-3, tol_schedule=[1e-7] * 20))
    with pytest.raises(KeyError, match="unknown ticket"):
        c.result(10_000)


def test_eager_submit_failure_leaks_no_ticket():
    """An inline execution error rejects atomically: no ticket is
    registered, so the session stays clean (KeyError, not a bogus
    'never completed' ClientError)."""
    c = client("inline")
    with pytest.raises(ValueError, match="align"):
        c.submit(PathSpec(problem=_instance("lasso", 0), n_points=5,
                          tol_schedule=[1e-3]))      # wrong length
    assert c.pending == 0
    with pytest.raises(KeyError, match="unknown ticket"):
        c.result(0)


# ------------------------------------------------------------------ #
# Config composition (the ServeConfig.max_batch wart, retired)       #
# ------------------------------------------------------------------ #
def test_client_config_composes_solver_and_serve():
    cfg = ClientConfig(solver=CFG,
                       serve=ServeConfig(max_batch=8), backend="wave")
    c = FlexaClient(cfg)
    assert c.config.serve.max_batch == 8
    assert c.backend == "wave"
    # overrides win over the config object's fields
    c2 = FlexaClient(cfg, backend="inline")
    assert c2.backend == "inline" and c2.config.serve.max_batch == 8


def test_wave_engine_accepts_serve_config_directly():
    """The satellite: no more hand-threading ``max_batch`` — the wave
    engine takes the same ServeConfig as the continuous engine, and the
    plain kwarg stays as a back-compat override."""
    from repro.serve import SolverServeEngine

    eng = SolverServeEngine(CFG, ServeConfig(max_batch=8))
    assert eng.max_batch == 8
    eng = SolverServeEngine(CFG, ServeConfig(max_batch=8), max_batch=2)
    assert eng.max_batch == 2              # explicit kwarg wins
    eng = SolverServeEngine(CFG)
    assert eng.max_batch == ServeConfig().max_batch
