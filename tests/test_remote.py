"""``repro.remote`` — wire protocol round-trips + live-server contracts.

Two layers:

1. **Protocol** (no server): the ndarray/problem/spec/result codecs
   round-trip bitwise, schema mismatches fail loudly, and telemetry
   snapshots survive a JSON round-trip under their frozen schema.
2. **Service** (subprocess on a loopback port): the remote backend's
   results match inline within the stack's 1e-5 envelope, quota
   rejections surface as the typed ``QuotaExceeded`` and stay observable
   in ``/stats``, past-deadline requests come back ``status="timeout"``
   through the normal eviction path, and SIGTERM drains gracefully
   (admitted work completes, telemetry is flushed, ``DRAINED`` printed).

The live tests share one module-scoped server running the calibrated
equivalence config (``tol=1e-7, tau_adapt off`` — the configuration the
backend matrix in test_client.py is calibrated against).
"""
import json
import os
import signal
import subprocess
import sys
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.client import (ClientConfig, FlexaClient, BatchSpec, CVSpec,
                          PathSpec, SoloSpec, UnsupportedWorkloadError,
                          normalize)
from repro.client.errors import ClientError
from repro.config.base import SolverConfig
from repro.problems.lasso import nesterov_instance
from repro.problems.logreg import random_logreg_instance
from repro.remote import QuotaExceeded, SCHEMA, protocol
from repro.remote.protocol import ProtocolError

SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")
CFG = SolverConfig(tol=1e-7, max_iters=4000, tau_adapt=False)
SERVER_ARGS = ["--tol", "1e-7", "--max-iters", "4000", "--no-tau-adapt"]


def _instance(family="lasso", seed=0, **kw):
    if family == "lasso":
        return nesterov_instance(m=24, n=64, nnz_frac=0.1, c=1.0,
                                 seed=seed, **kw)
    if family == "group_lasso":
        return nesterov_instance(m=24, n=64, nnz_frac=0.1, c=1.0,
                                 seed=seed, block_size=4)
    return random_logreg_instance(m=24, n=48, nnz_frac=0.15, c=0.5,
                                  seed=seed)


# ------------------------------------------------------------------ #
# 1a. ndarray codec                                                  #
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("dtype", ["float32", "float64", "int32", "bool"])
def test_array_roundtrip_bitwise(dtype):
    rng = np.random.default_rng(0)
    a = (rng.standard_normal((3, 5)) * 10).astype(dtype)
    out = protocol.decode_array(protocol.encode_array(a))
    assert out.dtype == a.dtype and out.shape == a.shape
    np.testing.assert_array_equal(out, a)


def test_array_roundtrip_survives_json():
    a = np.linspace(-1, 1, 7, dtype=np.float64)
    wire = json.loads(protocol.dumps({"a": protocol.encode_array(a)}))
    np.testing.assert_array_equal(protocol.decode_array(wire["a"]), a)


def test_array_none_passthrough_and_garbage_rejected():
    assert protocol.encode_array(None) is None
    assert protocol.decode_array(None) is None
    with pytest.raises(ProtocolError, match="not an encoded ndarray"):
        protocol.decode_array({"dtype": "float32"})


# ------------------------------------------------------------------ #
# 1b. Problem + spec codecs                                          #
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("family", ["lasso", "group_lasso", "logreg"])
def test_problem_roundtrip(family):
    p = _instance(family)
    q = protocol.decode_problem(
        json.loads(protocol.dumps(protocol.encode_problem(p))))
    assert q.family == p.family
    assert q.n == p.n and q.block_size == p.block_size
    assert q.g_kind == p.g_kind
    assert float(q.g_weight) == float(p.g_weight)
    for k in p.data:
        if k in json.loads(
                protocol.dumps(protocol.encode_problem(p)))["data"]:
            np.testing.assert_array_equal(np.asarray(q.data[k]),
                                          np.asarray(p.data[k], np.float32))


def _roundtrip_spec(spec):
    item = normalize(spec, ticket=0)
    wire = json.loads(protocol.dumps(protocol.encode_item(item)))
    return protocol.decode_spec(wire)


def test_spec_roundtrip_solo():
    x0 = np.zeros(64, np.float32)
    out = _roundtrip_spec(SoloSpec(problem=_instance(), x0=x0))
    assert type(out).__name__ == "SoloSpec"
    np.testing.assert_array_equal(out.x0, x0)


def test_spec_roundtrip_batch():
    out = _roundtrip_spec(BatchSpec(
        problems=[_instance(seed=s) for s in range(3)]))
    assert type(out).__name__ == "BatchSpec" and len(out.problems) == 3


def test_spec_roundtrip_path():
    out = _roundtrip_spec(PathSpec(problem=_instance(), n_points=4,
                                   lam_min_ratio=0.2, screen=True))
    assert type(out).__name__ == "PathSpec"
    assert out.n_points == 4 and out.lam_min_ratio == 0.2 and out.screen


def test_spec_roundtrip_cv_with_validation():
    folds = [_instance(seed=s) for s in range(2)]
    val = [(np.ones((4, 64), np.float32), np.ones(4, np.float32))
           for _ in folds]
    out = _roundtrip_spec(CVSpec(problems=folds, validation=val,
                                 tol_coarse=1e-3, n_points=3))
    assert type(out).__name__ == "CVSpec"
    assert out.tol_coarse == 1e-3 and len(out.validation) == 2
    np.testing.assert_array_equal(out.validation[0][0], val[0][0])


def test_unknown_schema_rejected():
    item = normalize(SoloSpec(problem=_instance()), ticket=0)
    wire = protocol.encode_item(item)
    wire["schema"] = SCHEMA + 1
    with pytest.raises(ProtocolError, match="schema"):
        protocol.decode_spec(wire)
    with pytest.raises(ProtocolError, match="schema"):
        protocol.decode_result({"schema": SCHEMA + 1, "kind": "solo",
                                "result": {}})


# ------------------------------------------------------------------ #
# 1c. Result codec (encode on "server", decode on "client")          #
# ------------------------------------------------------------------ #
@pytest.fixture(scope="module")
def inline_client():
    return FlexaClient(backend="inline", solver=CFG)


@pytest.mark.parametrize("kind,make_spec", [
    ("solo", lambda: SoloSpec(problem=_instance())),
    ("batch", lambda: BatchSpec(problems=[_instance(seed=s)
                                          for s in range(2)])),
    ("path", lambda: PathSpec(problem=_instance(), n_points=3)),
])
def test_result_roundtrip(kind, make_spec, inline_client):
    res = inline_client.run(make_spec())
    wire = json.loads(protocol.dumps(protocol.encode_result(kind, res)))
    out = protocol.decode_result(wire, backend="remote")
    if kind == "path":                       # PathResult stamps in meta
        assert out.meta["backend"] == "remote"
    else:
        assert out.backend == "remote"
    np.testing.assert_allclose(np.asarray(out.x), np.asarray(res.x),
                               rtol=0, atol=0)
    assert getattr(out, "raw", None) is None


def test_result_roundtrip_cv(inline_client):
    folds = [_instance(seed=s) for s in range(2)]
    val = [(np.asarray(_instance(seed=9 + s).data["A"]),
            np.asarray(_instance(seed=9 + s).data["b"]))
           for s in range(2)]
    res = inline_client.run(CVSpec(problems=folds, validation=val,
                                   n_points=3))
    wire = json.loads(protocol.dumps(protocol.encode_result("cv", res)))
    out = protocol.decode_result(wire, backend="remote")
    assert out.best_index == res.best_index
    assert out.best_lambda == pytest.approx(res.best_lambda)
    np.testing.assert_array_equal(np.asarray(out.scores),
                                  np.asarray(res.scores))
    np.testing.assert_array_equal(np.asarray(out.x_best),
                                  np.asarray(res.x_best))
    assert len(out.folds) == 2
    if res.ledger is not None:
        assert out.ledger.as_dict() == res.ledger.as_dict()


# ------------------------------------------------------------------ #
# 1d. Telemetry snapshot schema                                      #
# ------------------------------------------------------------------ #
def test_snapshot_schema_frozen_and_json_roundtrips():
    from repro.serve.metrics import SNAPSHOT_SCHEMA, ServeTelemetry
    tele = ServeTelemetry()
    rid = tele.next_request_id()
    tele.record_arrival(rid, "lasso", "continuous")
    tele.record_admit(rid)
    tele.record_completion(rid, iters=10, converged=True)
    tele.record_timeout()
    snap = tele.snapshot()
    assert snap["schema"] == SNAPSHOT_SCHEMA == 1
    again = json.loads(json.dumps(snap))
    assert again == snap
    assert again["health"]["timeouts"] == 1


def test_dashboard_schema_constant_mirrors_metrics():
    """dashboard stays import-light, so it duplicates the constant —
    this pin keeps the two in lockstep."""
    from repro.obs import dashboard
    from repro.serve.metrics import SNAPSHOT_SCHEMA
    assert dashboard.SNAPSHOT_SCHEMA == SNAPSHOT_SCHEMA


def test_dashboard_rejects_unknown_snapshot_schema():
    from repro.obs.dashboard import check_snapshot_schema
    check_snapshot_schema({"requests": 1})          # pre-versioning: ok
    check_snapshot_schema({"schema": 1})
    with pytest.raises(ValueError, match="only\\s+understands schema"):
        check_snapshot_schema({"schema": 99})


# ------------------------------------------------------------------ #
# 2. Live server                                                     #
# ------------------------------------------------------------------ #
def _spawn_server(extra_args=()):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.remote.server", "--port", "0",
         *SERVER_ARGS, *extra_args],
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, env=env,
        text=True)
    port = None
    for line in proc.stdout:
        if line.startswith("READY port="):
            port = int(line.split("=")[1])
            break
    if port is None:
        err = proc.stderr.read()
        proc.kill()
        raise RuntimeError(f"server failed to start:\n{err}")
    return proc, f"http://127.0.0.1:{port}"


@pytest.fixture(scope="module")
def server():
    proc, url = _spawn_server()
    yield url
    proc.send_signal(signal.SIGTERM)
    try:
        proc.wait(timeout=60)
    except subprocess.TimeoutExpired:
        proc.kill()


def _remote(url, **cfg):
    return FlexaClient(config=ClientConfig(
        backend="remote", remote_url=url, remote_tenant="pytest",
        solver=CFG, **cfg))


def test_remote_requires_url():
    with pytest.raises(ClientError, match="remote_url"):
        FlexaClient(config=ClientConfig(backend="remote"))


def test_remote_rejects_score_callable(server):
    c = _remote(server)
    with pytest.raises(UnsupportedWorkloadError, match="wire"):
        c.submit(CVSpec(problems=[_instance(seed=s) for s in range(2)],
                        score=lambda prob, x, lam: 0.0))


@pytest.mark.parametrize("family", ["lasso", "logreg"])
def test_remote_solo_matches_inline(server, family, inline_client):
    ref = inline_client.run(SoloSpec(problem=_instance(family)))
    got = _remote(server).run(SoloSpec(problem=_instance(family)))
    assert got.backend == "remote" and got.converged
    np.testing.assert_allclose(np.asarray(got.x), np.asarray(ref.x),
                               atol=1e-5)


def test_remote_path_matches_inline(server, inline_client):
    spec = dict(n_points=4, lam_min_ratio=0.2)
    ref = inline_client.run(PathSpec(problem=_instance("group_lasso"),
                                     **spec))
    got = _remote(server).run(PathSpec(problem=_instance("group_lasso"),
                                       **spec))
    np.testing.assert_allclose(got.lambdas, ref.lambdas, rtol=1e-12)
    np.testing.assert_allclose(np.asarray(got.x), np.asarray(ref.x),
                               atol=1e-5)


def test_remote_quota_in_flight_typed_rejection():
    """A dedicated 1-slot server: the second concurrent submit raises
    the typed QuotaExceeded, and the rejection is visible in /stats.

    ``tol=-1`` makes the first request run its full (small) iteration
    budget, so it is deterministically still in flight when the second
    submit arrives — no race against a fast solve."""
    proc, url = _spawn_server(["--max-in-flight", "1", "--tol", "-1",
                               "--max-iters", "2000",
                               "--chunk-iters", "4"])
    try:
        c = _remote(url)
        t1 = c.submit(SoloSpec(problem=_instance()))
        with pytest.raises(QuotaExceeded) as ei:
            c.submit(SoloSpec(problem=_instance(seed=1)))
        assert ei.value.reason == "in_flight"
        assert ei.value.tenant == "pytest"
        assert c.result(t1).iters == 2000    # first ticket unharmed
        stats = c._backend.stats()["server"]
        ten = stats["tenants"]["pytest"]
        assert ten["rejected"]["in_flight"] == 1
        assert ten["in_flight"] == 0         # released on completion
        # Slot free again: submission resumes.
        assert c.run(SoloSpec(problem=_instance(seed=2))).iters == 2000
    finally:
        proc.send_signal(signal.SIGTERM)
        proc.wait(timeout=60)


def test_remote_past_deadline_times_out(server):
    """deadline_s=0 expires before the first chunk: the server answers
    through the normal eviction path with status="timeout"."""
    item = normalize(SoloSpec(problem=_instance()), ticket=0)
    msg = protocol.encode_item(item)
    msg.update(tenant="pytest", slo="interactive", deadline_s=0.0)
    req = urllib.request.Request(
        f"{server}/v1/submit", data=protocol.dumps(msg), method="POST")
    with urllib.request.urlopen(req, timeout=30) as resp:
        ticket = json.loads(resp.read())["ticket"]
    with urllib.request.urlopen(
            f"{server}/v1/result/{ticket}?wait_ms=20000",
            timeout=60) as resp:
        out = protocol.decode_result(json.loads(resp.read()))
    assert out.status == "timeout"
    assert not out.converged and out.iters == 0


def test_remote_sigterm_drains_gracefully(tmp_path):
    """SIGTERM with work in flight: admitted work completes, telemetry
    is flushed to --telemetry-out, DRAINED is printed, exit code 0."""
    out_file = tmp_path / "final_snapshot.json"
    proc, url = _spawn_server(["--telemetry-out", str(out_file)])
    c = _remote(url)
    t = c.submit(SoloSpec(problem=_instance()))
    proc.send_signal(signal.SIGTERM)
    # Draining, not dead: the in-flight ticket still completes.
    res = c.result(t)
    assert res.converged
    out, _ = proc.communicate(timeout=120)
    assert proc.returncode == 0
    assert "DRAINED" in out
    snap = json.loads(out_file.read_text())
    assert snap["schema"] == SCHEMA
    assert snap["telemetry"]["completed"] == 1
    # Post-drain: new submissions are refused (server gone).
    with pytest.raises(ClientError):
        c.submit(SoloSpec(problem=_instance(seed=3)))
