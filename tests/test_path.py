"""Regularization-path engine tests (``repro.path``).

Covers the tentpole's correctness obligations:

* λ_max is the exact all-zero threshold;
* warm-vs-cold equivalence ≤ 1e-5 at every grid point (screening
  exactness — the strong rule + KKT recheck may only change *work*,
  never answers);
* the screening-safety property: no block carrying signal in the cold
  reference solution is ever left frozen in the final answer (every
  strong-rule rejection is KKT-rechecked);
* the fold-batched lockstep sweep matches per-instance sequential paths;
* a golden fixed-seed path trajectory (per-λ objective values) guarding
  the homotopy/screening plumbing against silent drift — regenerate
  after an intentional change with:

      PYTHONPATH=src python tests/test_path.py --regen
"""
import dataclasses
import json
from pathlib import Path

import numpy as np
import pytest

from repro.config.base import SolverConfig
from repro.path import geometric_grid, lambda_max, validate_grid
from repro.path.driver import (_solve_path as solve_path,
                               _solve_path_batched as solve_path_batched)
from repro.path.screening import kkt_violations, strong_rule_active
from repro.problems.lasso import nesterov_instance
from repro.solvers.api import _solve as solve

GOLDEN_DIR = Path(__file__).resolve().parent / "golden"
GOLDEN = GOLDEN_DIR / "path_lasso_V.json"

#: One small planted instance + a budget every test shares.  Fixed τ and
#: tol 1e-7: honest stationarity at stopping (see docs/paths.md), so the
#: 1e-5 equivalence assertions have margin over the fp32 noise floor.
INSTANCE = dict(m=30, n=96, nnz_frac=0.1, c=1.0, seed=0)
CFG = SolverConfig(tol=1e-7, max_iters=4000, tau_adapt=False)
GRID = dict(n_points=10, lam_min_ratio=0.05)


@pytest.fixture(scope="module")
def lasso():
    return nesterov_instance(**INSTANCE)


@pytest.fixture(scope="module")
def cold_path(lasso):
    return solve_path(lasso, cfg=CFG, warm=False, screen=False, **GRID)


@pytest.fixture(scope="module")
def ws_path(lasso):
    return solve_path(lasso, cfg=CFG, warm=True, screen=True, **GRID)


# ------------------------------------------------------------------ #
# Grid layer                                                         #
# ------------------------------------------------------------------ #
def test_lambda_max_is_zero_threshold(lasso):
    lm = lambda_max(lasso)
    above = solve(dataclasses.replace(lasso, g_weight=1.01 * lm), cfg=CFG)
    assert float(np.abs(np.asarray(above.x)).max()) == 0.0
    below = solve(dataclasses.replace(lasso, g_weight=0.9 * lm), cfg=CFG)
    assert float(np.abs(np.asarray(below.x)).max()) > 0.0


def test_geometric_grid_properties():
    g = geometric_grid(10.0, n_points=7, lam_min_ratio=0.01)
    assert g.shape == (7,) and g[0] == pytest.approx(10.0)
    assert g[-1] == pytest.approx(0.1)
    assert np.all(np.diff(g) < 0)
    g2 = geometric_grid(10.0, n_points=7, lam_min_ratio=0.01,
                        include_max=False)
    assert g2[0] < 10.0 and np.all(np.diff(g2) < 0)


def test_validate_grid_rejects_bad_grids():
    with pytest.raises(ValueError):
        validate_grid([1.0, 2.0])           # increasing
    with pytest.raises(ValueError):
        validate_grid([1.0, -0.5])          # nonpositive
    with pytest.raises(ValueError):
        validate_grid([])


# ------------------------------------------------------------------ #
# Screening rules (unit level)                                       #
# ------------------------------------------------------------------ #
def test_strong_rule_keeps_warm_support_and_hot_scores():
    scores = np.array([5.0, 0.1, 2.9, 0.0])
    # threshold 2*2 - 3 = 1: keep blocks 0 and 2...
    act = strong_rule_active(scores, c_new=2.0, c_prev=3.0)
    np.testing.assert_array_equal(act, [1, 0, 1, 0])
    # ...and anything nonzero in the warm start, whatever its score.
    act = strong_rule_active(scores, 2.0, 3.0,
                             warm_block_norms=np.array([0, 0, 0, 7.0]))
    np.testing.assert_array_equal(act, [1, 0, 1, 1])
    with pytest.raises(ValueError):
        strong_rule_active(scores, 3.0, 2.0)    # not decreasing


def test_kkt_violations_only_flags_frozen_blocks():
    scores = np.array([9.0, 1.5, 0.5, 3.0])
    active = np.array([1.0, 0.0, 0.0, 0.0])
    viol = kkt_violations(scores, active, c=1.0, slack=1e-3)
    # block 0 is active (solver's job), 1 and 3 are frozen violators,
    # 2 is frozen but satisfies KKT.
    np.testing.assert_array_equal(viol, [0, 1, 0, 1])


# ------------------------------------------------------------------ #
# Path driver: exactness + safety                                    #
# ------------------------------------------------------------------ #
def test_warm_vs_cold_equivalence_per_lambda(cold_path, ws_path):
    dev = np.max(np.abs(ws_path.x - cold_path.x), axis=1)
    assert dev.max() <= 1e-5, dev
    # Both ends actually did something: first point is the certified
    # zero solution, later supports grow.
    assert ws_path.support[0] == 0
    assert ws_path.support[-1] > 0
    assert np.all(ws_path.converged)


def test_path_trivial_head_is_exact_zero(ws_path):
    assert ws_path.lambdas[0] == pytest.approx(ws_path.lam_max)
    assert ws_path.iters[0] == 0
    assert float(np.abs(ws_path.x[0]).max()) == 0.0


@pytest.mark.parametrize("seed", [1, 2, 3])
def test_screening_safety_no_signal_block_left_frozen(seed):
    """Property: every block carrying signal in the cold reference is
    live (unfrozen, correctly valued) in the screened path — the strong
    rule's mistakes must all be caught by the KKT recheck."""
    p = nesterov_instance(**{**INSTANCE, "seed": seed})
    cold = solve_path(p, cfg=CFG, warm=False, screen=False, **GRID)
    ws = solve_path(p, cfg=CFG, warm=True, screen=True, **GRID)
    for k in range(cold.n_points):
        signal = np.abs(cold.x[k]) > 1e-4
        # a frozen block sits exactly at zero; signal blocks must not
        assert not np.any(signal & (ws.x[k] == 0.0)), (
            f"λ[{k}]: screened path froze a signal block")
        np.testing.assert_allclose(ws.x[k], cold.x[k], atol=1e-5)
    # screening actually screened (the property is vacuous otherwise)
    assert sum(r.screened_out for r in ws.screened) > 0


def test_final_solutions_satisfy_kkt(lasso, ws_path):
    """Exactness certificate independent of the cold reference: at every
    λ, frozen/zero blocks satisfy |∇F| ≤ c (with the documented slack)
    and the solver drove the live blocks' stationarity below tol."""
    import jax.numpy as jnp
    for k in range(ws_path.n_points):
        ck = float(ws_path.lambdas[k])
        g = np.asarray(lasso.grad_f(jnp.asarray(ws_path.x[k])))
        zero = ws_path.x[k] == 0.0
        assert np.all(np.abs(g[zero]) <= ck * (1 + 2e-3) + 1e-5), k


def test_group_lasso_path_equivalence():
    # Grid stops at 0.15·λ_max: deeper grids grow borderline groups
    # whose norms sit at ~2e-5 — both solves converge at tol but group
    # soft-threshold membership of such groups is not pinned at fp32
    # (same class of boundary noise PR 1 documented for τ branching).
    p = nesterov_instance(m=48, n=96, nnz_frac=0.1, c=1.0, seed=1,
                          block_size=4)
    cold = solve_path(p, cfg=CFG, n_points=6, lam_min_ratio=0.15,
                      warm=False, screen=False)
    ws = solve_path(p, cfg=CFG, n_points=6, lam_min_ratio=0.15,
                    warm=True, screen=True)
    np.testing.assert_allclose(ws.x, cold.x, atol=1e-5)
    assert sum(r.screened_out for r in ws.screened) > 0


def test_tol_schedule_coarse_to_fine(lasso, ws_path):
    """Per-λ tol continuation: loose tolerances on the early points cut
    their work, the full-tol tail still lands on the reference solution,
    and a misaligned schedule is rejected."""
    P = GRID["n_points"]
    sched = np.full(P, 1e-3)
    sched[-1] = CFG.tol                      # full accuracy at the end
    r = solve_path(lasso, cfg=CFG, warm=True, screen=True,
                   tol_schedule=sched, **GRID)
    assert r.meta["tol_schedule"][-1] == CFG.tol
    assert int(r.iters.sum()) < int(ws_path.iters.sum())
    np.testing.assert_allclose(r.x[-1], ws_path.x[-1], atol=1e-5)
    with pytest.raises(ValueError, match="align"):
        solve_path(lasso, cfg=CFG, tol_schedule=[1e-3], **GRID)


def test_lam_batch_chunked_matches_sequential(lasso, ws_path):
    chunked = solve_path(lasso, cfg=CFG, warm=True, screen=True,
                         lam_batch=4, **GRID)
    np.testing.assert_allclose(chunked.x, ws_path.x, atol=1e-5)
    # chunk device accounting: B rows × slowest point in each chunk
    assert chunked.row_iters >= int(chunked.iters.sum())


def test_unscreenable_family_rejected(monkeypatch):
    """A family whose ``screen_scores`` hook is absent must be rejected
    loudly (and still allowed unscreened).  All four built-in families
    now carry hooks, so simulate a hookless one."""
    import repro.problems.families as fams
    from repro.problems.logreg import random_logreg_instance

    bare = dataclasses.replace(fams._FAMILIES["logreg"],
                               screen_scores=None)
    monkeypatch.setitem(fams._FAMILIES, "logreg", bare)
    p = random_logreg_instance(m=20, n=32, nnz_frac=0.2, seed=0)
    with pytest.raises(ValueError, match="screening hook"):
        solve_path(p, cfg=CFG, n_points=4)
    # ...but an unscreened path is allowed for any family.
    r = solve_path(p, cfg=CFG, n_points=4, lam_min_ratio=0.2,
                   screen=False)
    assert np.all(r.converged)


# ------------------------------------------------------------------ #
# Newly screenable families (logreg / svm) — safety property          #
# ------------------------------------------------------------------ #
#: tol 1e-8 for the nonquadratic families: their warm-vs-cold stopping
#: noise at 1e-7 was measured at ~2e-5 (the two paths stop at different
#: fp32 stationarity points); one decade tighter brings the comparison
#: under the shared 1e-5 exactness gate with margin.  Screening itself
#: was measured bit-identical to the unscreened warm path (the verdict
#: recorded on families._grad_block_scores).
NONQUAD_CFG = SolverConfig(tol=1e-8, max_iters=20_000, tau_adapt=False)


@pytest.mark.parametrize("family,make", [
    ("logreg", lambda s: __import__(
        "repro.problems.logreg", fromlist=["random_logreg_instance"]
    ).random_logreg_instance(m=40, n=80, nnz_frac=0.1, c=0.5, seed=s)),
    ("svm", lambda s: __import__(
        "repro.problems.svm", fromlist=["random_svm_instance"]
    ).random_svm_instance(m=40, n=80, nnz_frac=0.1, c=0.5, seed=s)),
])
@pytest.mark.parametrize("seed", [0, 1])
def test_screening_safety_newly_screenable_families(family, make, seed):
    """Property (per newly screenable family): the screened path equals
    the cold reference at every λ, no signal block is left frozen, and
    the strong rule actually froze something (non-vacuous)."""
    p = make(seed)
    assert p.family == family
    grid_kw = dict(n_points=6, lam_min_ratio=0.05)
    cold = solve_path(p, cfg=NONQUAD_CFG, warm=False, screen=False,
                      **grid_kw)
    ws = solve_path(p, cfg=NONQUAD_CFG, warm=True, screen=True,
                    **grid_kw)
    for k in range(cold.n_points):
        signal = np.abs(cold.x[k]) > 1e-4
        assert not np.any(signal & (ws.x[k] == 0.0)), (
            f"{family} λ[{k}]: screened path froze a signal block")
        np.testing.assert_allclose(ws.x[k], cold.x[k], atol=1e-5)
    assert sum(r.screened_out for r in ws.screened) > 0
    # (no ws.converged assert: 1e-8 sits at the fp32 stationarity floor
    # and an occasional point runs to the iteration cap — the per-λ
    # equality above is the property being pinned.)


# ------------------------------------------------------------------ #
# Fold-batched lockstep sweep (the CV substrate)                     #
# ------------------------------------------------------------------ #
def test_path_batched_matches_sequential_paths():
    ps = [nesterov_instance(**{**INSTANCE, "seed": s}) for s in (0, 1)]
    lam = max(lambda_max(p) for p in ps)
    grid = geometric_grid(lam, n_points=6, lam_min_ratio=0.1)
    batched = solve_path_batched(ps, lambdas=grid, cfg=CFG)
    for p, r in zip(ps, batched):
        solo = solve_path(p, lambdas=grid, cfg=CFG)
        np.testing.assert_allclose(r.x, solo.x, atol=1e-5)
        assert np.all(r.converged)
    # one fold's λ_max is below the shared grid head: its head points
    # must come out (near) zero, not garbage
    i_small = int(np.argmin([lambda_max(p) for p in ps]))
    assert float(np.abs(batched[i_small].x[0]).max()) <= 1e-5


# ------------------------------------------------------------------ #
# Golden fixed-seed trajectory                                       #
# ------------------------------------------------------------------ #
# V values are O(1..10); 5e-4 relative sits ~1000x above fp32
# reduction-order noise and far below any real math change (same
# rationale as tests/test_golden_convergence.py).
GOLDEN_RTOL = 5e-4


def _golden_record(ws):
    return {
        "instance": INSTANCE,
        "grid": GRID,
        "cfg": {"tol": CFG.tol, "max_iters": CFG.max_iters,
                "tau_adapt": CFG.tau_adapt},
        "lam_max": float(ws.lam_max),
        "lambdas": [float(l) for l in ws.lambdas],
        "V": [float(v) for v in ws.V],
        "support": [int(s) for s in ws.support],
        "screened_out": [r.screened_out for r in ws.screened],
    }


def test_path_trajectory_matches_golden(ws_path):
    assert GOLDEN.exists(), (
        f"golden file {GOLDEN} missing — regenerate with "
        "`PYTHONPATH=src python tests/test_path.py --regen`")
    gold = json.loads(GOLDEN.read_text())
    assert gold["instance"] == INSTANCE and gold["grid"] == GRID, \
        "golden file was generated for a different instance/grid"
    assert gold["lam_max"] == pytest.approx(ws_path.lam_max, rel=1e-6)
    np.testing.assert_allclose(
        np.asarray(ws_path.V), np.asarray(gold["V"]), rtol=GOLDEN_RTOL,
        err_msg="per-λ objective trajectory drifted from tests/golden — "
                "if the homotopy/screening math changed intentionally, "
                "regenerate (see module docstring)")
    # Support sizes are integers with healthy margins at this seed; a
    # drift here means the screening/prox plumbing changed.
    assert gold["support"] == [int(s) for s in ws_path.support]


# ------------------------------------------------------------------ #
# Full-scale sweep (slow tier)                                       #
# ------------------------------------------------------------------ #
@pytest.mark.slow
def test_path_bench_full_acceptance():
    """The full BENCH_path gate: ≥20-point grid, ≥2× device
    row-iterations vs the cold batched grid, ≤1e-5 per-λ deviation, and
    the CV-over-serve sweep matching the lockstep driver."""
    import sys
    from pathlib import Path as _P
    sys.path.insert(0, str(_P(__file__).resolve().parent.parent))
    from benchmarks import path_bench

    art = path_bench.main()
    acc = art["path"]["accept"]
    assert art["accept_ok"], acc
    assert acc["grid_points"] >= 20
    assert acc["ratio_vs_cold_batched"] >= 2.0
    assert acc["max_dev"] <= 1e-5
    assert art["cv"]["serve_matches_lockstep"]


def regenerate() -> None:
    p = nesterov_instance(**INSTANCE)
    ws = solve_path(p, cfg=CFG, warm=True, screen=True, **GRID)
    GOLDEN_DIR.mkdir(parents=True, exist_ok=True)
    GOLDEN.write_text(json.dumps(_golden_record(ws), indent=1))
    print(f"wrote {GOLDEN} ({ws.n_points} points, "
          f"supports {list(ws.support)})")


if __name__ == "__main__":
    import sys
    if "--regen" in sys.argv:
        regenerate()
    else:
        print(__doc__)
