"""Public-API surface snapshot + deprecation-shim contracts.

Three things are pinned here:

1. the exact public exports of ``repro.solvers`` / ``repro.serve`` /
   ``repro.path`` / ``repro.client`` (an intentional API change must
   edit the snapshot — an accidental one fails loudly);
2. every legacy entry point *delegates to the client path* (the shims
   construct a FlexaClient and hand it the equivalent spec — verified
   by interception, not by trusting the docstring);
3. the one-shot FutureWarning contract: each legacy entry point warns
   exactly once per process, and the client's own backends never
   trigger the warnings (they run under ``deprecation.internal_use``).
"""
import warnings

import numpy as np
import pytest

import repro.client
import repro.path
import repro.serve
import repro.solvers
from repro import deprecation
from repro.config.base import ServeConfig, SolverConfig
from repro.problems.lasso import nesterov_instance

# ------------------------------------------------------------------ #
# 1. Surface snapshot                                                #
# ------------------------------------------------------------------ #
SURFACE = {
    "repro.solvers": [
        "BatchedProblemSpec", "SlabState", "SolverResult",
        "available_methods", "cache_stats", "get_solver",
        "make_batched_solver", "make_chunk_stepper",
        "make_sharded_chunk_stepper", "make_slot_writer",
        "register", "slab_alloc", "solve", "solve_batched",
    ],
    "repro.serve": [
        "AdmissionQueue", "ContinuousSolverEngine", "GenerationResult",
        "MeshServeEngine", "MeshTelemetry",
        "PathRequest", "PathState", "QueueEntry", "RequestTrace",
        "ServeEngine", "ServeTelemetry", "SolveRequest", "SolveResponse",
        "SolverServeEngine",
    ],
    "repro.path": [
        "DEFAULT_KKT_SLACK", "MAX_KKT_ROUNDS", "PathResult",
        "ScreenReport", "block_scores", "geometric_grid",
        "kkt_violations", "lambda_max", "solve_path",
        "solve_path_batched", "strong_rule_active", "validate_grid",
    ],
    "repro.client": [
        "Backend", "BatchResult", "BatchSpec", "CVResult", "CVSpec",
        "ClientConfig", "ClientError", "ContinuousBackend",
        "FlexaClient", "InlineBackend", "MeshBackend", "PathResult",
        "PathSpec",
        "SoloResult", "SoloSpec", "SpecError", "TicketDiagnostics",
        "UnknownBackendError",
        "UnsupportedWorkloadError", "WaveBackend", "WorkItem",
        "available_backends", "make_backend", "normalize",
        "register_backend", "solve_request_of",
    ],
    "repro.obs": [
        "CostLedger", "HealthConfig", "LEDGER_KEYS", "MetricWindows",
        "SlidingWindow", "SolveFailure", "Span", "Tracer",
        "allclose_or_both_nonfinite", "assert_finite_close",
        "bitwise_equal", "get_tracer", "instant", "render_requests",
        "render_snapshot", "set_tracer", "span", "sparkline", "tracing",
    ],
}


@pytest.mark.parametrize("module", sorted(SURFACE))
def test_public_surface_snapshot(module):
    import importlib
    mod = importlib.import_module(module)
    assert sorted(mod.__all__) == SURFACE[module], (
        f"{module}.__all__ drifted — if the API change is intentional, "
        "update the snapshot in tests/test_api_surface.py")
    for name in mod.__all__:
        assert hasattr(mod, name), f"{module}.{name} exported but absent"


# ------------------------------------------------------------------ #
# 2. Shim delegation                                                 #
# ------------------------------------------------------------------ #
@pytest.fixture
def mini():
    return nesterov_instance(m=16, n=32, nnz_frac=0.2, c=1.0, seed=0)


LEGACY = [
    (lambda p: repro.solvers.solve(p), "SoloSpec"),
    (lambda p: repro.solvers.solve_batched([p]), "BatchSpec"),
    (lambda p: repro.path.solve_path(p, n_points=3), "PathSpec"),
    (lambda p: repro.path.solve_path_batched([p], n_points=3), "CVSpec"),
]


@pytest.mark.parametrize("call,spec_name",
                         LEGACY, ids=[s for _, s in LEGACY])
def test_legacy_entry_points_delegate_to_client(call, spec_name, mini,
                                                monkeypatch):
    """Intercept FlexaClient.run: each legacy call must route through
    the client with the matching spec type."""
    from types import SimpleNamespace

    from repro.client.session import FlexaClient

    seen = []

    def fake_run(self, spec):
        seen.append(type(spec).__name__)
        return SimpleNamespace(raw="raw-sentinel",
                               folds=["folds-sentinel"])

    monkeypatch.setattr(FlexaClient, "run", fake_run)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", FutureWarning)
        out = call(mini)
    assert seen == [spec_name]
    # solo/batch shims unwrap .raw, the fold sweep unwraps .folds, and
    # the path shim returns the client's PathResult as-is.
    assert out == "raw-sentinel" or out == ["folds-sentinel"] \
        or getattr(out, "raw", None) == "raw-sentinel"


def test_legacy_solve_returns_identical_result(mini):
    """Delegation is transparent: the shim's answer is bitwise the
    inline implementation's answer, full history contract included."""
    from repro.solvers.api import _solve

    cfg = SolverConfig(max_iters=50, tol=0)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", FutureWarning)
        shim = repro.solvers.solve(mini, cfg=cfg)
    ref = _solve(mini, cfg=cfg)
    np.testing.assert_array_equal(np.asarray(shim.x), np.asarray(ref.x))
    assert shim.iters == ref.iters
    assert len(shim.history["V"]) == len(ref.history["V"])


# ------------------------------------------------------------------ #
# 3. One-shot FutureWarning                                          #
# ------------------------------------------------------------------ #
def _future_warnings(w):
    return [x for x in w if issubclass(x.category, FutureWarning)]


def test_futurewarning_fires_exactly_once_per_entry_point(mini):
    deprecation.reset_warnings()
    try:
        cfg = SolverConfig(max_iters=5, tol=0)
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            repro.solvers.solve(mini, cfg=cfg)
            repro.solvers.solve(mini, cfg=cfg)      # second call: silent
        fw = _future_warnings(w)
        assert len(fw) == 1
        assert "repro.solvers.solve" in str(fw[0].message)
        assert "FlexaClient" in str(fw[0].message)

        # A *different* entry point still announces itself once.
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            repro.solvers.solve_batched([mini], cfg=cfg)
            repro.solvers.solve_batched([mini], cfg=cfg)
        assert len(_future_warnings(w)) == 1
    finally:
        deprecation.reset_warnings()


def test_engine_construction_warns_once(mini):
    deprecation.reset_warnings()
    try:
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            repro.serve.SolverServeEngine(SolverConfig(max_iters=5))
            repro.serve.SolverServeEngine(SolverConfig(max_iters=5))
            repro.serve.ContinuousSolverEngine(
                SolverConfig(max_iters=5), ServeConfig(slab_capacity=2))
        fw = _future_warnings(w)
        assert len(fw) == 2                 # one per engine class
    finally:
        deprecation.reset_warnings()


def test_client_backends_never_trigger_legacy_warnings(mini):
    """The front door must not warn about the machinery it fronts."""
    from repro.client import FlexaClient, SoloSpec

    deprecation.reset_warnings()
    try:
        cfg = SolverConfig(tol=1e-6, max_iters=500, tau_adapt=False)
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            for backend in ("inline", "wave", "continuous", "mesh"):
                FlexaClient(backend=backend, solver=cfg).run(
                    SoloSpec(problem=mini))
        assert _future_warnings(w) == []
    finally:
        deprecation.reset_warnings()
