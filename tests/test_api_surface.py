"""Public-API surface snapshot + deprecation contracts.

Three things are pinned here:

1. the exact public exports of ``repro.solvers`` / ``repro.serve`` /
   ``repro.path`` / ``repro.client`` / ``repro.obs`` / ``repro.remote``
   (an intentional API change must edit the snapshot — an accidental
   one fails loudly);
2. the legacy entry points (``solve``/``solve_batched``/``solve_path*``)
   are **gone**: their FutureWarning deprecation cycle completed and the
   shims were removed — ``FlexaClient`` is the front door, the
   ``_solve*`` internals stay importable for the engine layer and tests;
3. what remains of the warning contract: raw engine construction still
   warns once per process, and the client's own backends never trigger
   the warnings (they run under ``deprecation.internal_use``).
"""
import warnings

import pytest

import repro.client
import repro.path
import repro.serve
import repro.solvers
from repro import deprecation
from repro.config.base import ServeConfig, SolverConfig
from repro.problems.lasso import nesterov_instance

# ------------------------------------------------------------------ #
# 1. Surface snapshot                                                #
# ------------------------------------------------------------------ #
SURFACE = {
    "repro.solvers": [
        "BatchedProblemSpec", "SlabState", "SolverResult",
        "available_methods", "cache_stats", "get_solver",
        "make_batched_solver", "make_chunk_stepper",
        "make_sharded_chunk_stepper", "make_slot_writer",
        "register", "slab_alloc",
    ],
    "repro.serve": [
        "AdmissionQueue", "ContinuousSolverEngine", "GenerationResult",
        "MeshServeEngine", "MeshTelemetry",
        "PathRequest", "PathState", "QueueEntry", "RequestTrace",
        "ServeEngine", "ServeTelemetry", "SolveRequest", "SolveResponse",
        "SolverServeEngine",
    ],
    "repro.path": [
        "DEFAULT_KKT_SLACK", "MAX_KKT_ROUNDS", "PathResult",
        "ScreenReport", "block_scores", "geometric_grid",
        "kkt_violations", "lambda_max", "strong_rule_active",
        "validate_grid",
    ],
    "repro.client": [
        "Backend", "BatchResult", "BatchSpec", "CVResult", "CVSpec",
        "ClientConfig", "ClientError", "ContinuousBackend",
        "FlexaClient", "InlineBackend", "MeshBackend", "PathResult",
        "PathSpec",
        "SoloResult", "SoloSpec", "SpecError", "TicketDiagnostics",
        "UnknownBackendError",
        "UnsupportedWorkloadError", "WaveBackend", "WorkItem",
        "available_backends", "make_backend", "normalize",
        "register_backend", "solve_request_of",
    ],
    "repro.obs": [
        "CostLedger", "HealthConfig", "LEDGER_KEYS", "MetricWindows",
        "SlidingWindow", "SolveFailure", "Span", "Tracer",
        "allclose_or_both_nonfinite", "assert_finite_close",
        "bitwise_equal", "get_tracer", "instant", "render_requests",
        "render_snapshot", "set_tracer", "span", "sparkline", "tracing",
    ],
    "repro.remote": [
        "ProtocolError", "QuotaExceeded", "QuotaPolicy", "SCHEMA",
        "SLOClass", "SLO_CLASSES", "TenantQuota", "TokenBucket",
        "decode_array", "decode_result", "decode_spec", "encode_array",
        "encode_item", "encode_result", "resolve_slo",
    ],
}


@pytest.mark.parametrize("module", sorted(SURFACE))
def test_public_surface_snapshot(module):
    import importlib
    mod = importlib.import_module(module)
    assert sorted(mod.__all__) == SURFACE[module], (
        f"{module}.__all__ drifted — if the API change is intentional, "
        "update the snapshot in tests/test_api_surface.py")
    for name in mod.__all__:
        assert hasattr(mod, name), f"{module}.{name} exported but absent"


def test_remote_package_stays_lazy():
    """``import repro.remote`` exposes only policy + protocol; the
    server and the registered backend are imported on demand (the
    client registry pulls ``repro.remote.backend`` the first time
    ``backend="remote"`` is requested)."""
    import sys
    import repro.remote  # noqa: F401
    assert "repro.remote.server" not in sys.modules
    assert "repro.remote.backend" not in sys.modules


# ------------------------------------------------------------------ #
# 2. The legacy shims completed their deprecation cycle              #
# ------------------------------------------------------------------ #
REMOVED = [
    ("repro.solvers", "solve"),
    ("repro.solvers", "solve_batched"),
    ("repro.path", "solve_path"),
    ("repro.path", "solve_path_batched"),
]


@pytest.mark.parametrize("module,name", REMOVED,
                         ids=[f"{m}.{n}" for m, n in REMOVED])
def test_legacy_entry_points_removed(module, name):
    """PR 5 wrapped these in one-shot FutureWarnings pointing at
    FlexaClient; this PR removes them.  Anything still calling one
    should fail with AttributeError, not silently bypass the client."""
    import importlib
    mod = importlib.import_module(module)
    assert not hasattr(mod, name)
    assert name not in mod.__all__


def test_internal_entry_points_still_importable():
    """The underscore internals the shims delegated to remain — the
    engine layer and the test suite build on them."""
    from repro.path.driver import _solve_path, _solve_path_batched
    from repro.solvers.api import _solve
    from repro.solvers.batched import _solve_batched
    assert all(callable(f) for f in
               (_solve, _solve_batched, _solve_path, _solve_path_batched))


# ------------------------------------------------------------------ #
# 3. The remaining warning contract                                  #
# ------------------------------------------------------------------ #
def _future_warnings(w):
    return [x for x in w if issubclass(x.category, FutureWarning)]


@pytest.fixture
def mini():
    return nesterov_instance(m=16, n=32, nnz_frac=0.2, c=1.0, seed=0)


def test_engine_construction_warns_once(mini):
    deprecation.reset_warnings()
    try:
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            repro.serve.SolverServeEngine(SolverConfig(max_iters=5))
            repro.serve.SolverServeEngine(SolverConfig(max_iters=5))
            repro.serve.ContinuousSolverEngine(
                SolverConfig(max_iters=5), ServeConfig(slab_capacity=2))
        fw = _future_warnings(w)
        assert len(fw) == 2                 # one per engine class
    finally:
        deprecation.reset_warnings()


def test_client_backends_never_trigger_legacy_warnings(mini):
    """The front door must not warn about the machinery it fronts."""
    from repro.client import FlexaClient, SoloSpec

    deprecation.reset_warnings()
    try:
        cfg = SolverConfig(tol=1e-6, max_iters=500, tau_adapt=False)
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            for backend in ("inline", "wave", "continuous", "mesh"):
                FlexaClient(backend=backend, solver=cfg).run(
                    SoloSpec(problem=mini))
        assert _future_warnings(w) == []
    finally:
        deprecation.reset_warnings()
