"""Behavioural tests for Algorithm 1 (the paper's core claims)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.config.base import SolverConfig
from repro.core import flexa, selection
from repro.problems.group_lasso import nesterov_group_instance
from repro.problems.lasso import nesterov_instance
from repro.problems.logreg import random_logreg_instance
from repro.problems.svm import random_svm_instance


@pytest.fixture(scope="module")
def lasso():
    return nesterov_instance(m=80, n=400, nnz_frac=0.1, c=1.0, seed=0)


def rel_err(problem, v):
    return (v - problem.v_star) / problem.v_star


def test_flexa_converges_to_planted_optimum(lasso):
    r = flexa.solve(lasso, cfg=SolverConfig(max_iters=600, tol=1e-8))
    assert rel_err(lasso, r.history["V"][-1]) < 1e-5
    # support recovery: large entries of x* found
    x = np.asarray(r.x)
    xs = np.asarray(lasso.x_star)
    big = np.abs(xs) > 0.2
    assert (np.abs(x[big]) > 0.05).all()


def test_greedy_beats_full_jacobi(lasso):
    """Paper §4: updating a greedy ρ-subset converges faster than all."""
    rg = flexa.solve(lasso, cfg=SolverConfig(max_iters=300, tol=0))
    rj = flexa.solve(lasso, cfg=SolverConfig(max_iters=300, tol=0,
                                             jacobi=True))
    assert rg.history["V"][-1] <= rj.history["V"][-1] * 1.05


def test_monotone_descent_after_burnin(lasso):
    """With the τ controller active, V decreases (allowing brief τ bumps)."""
    r = flexa.solve(lasso, cfg=SolverConfig(max_iters=200, tol=0))
    V = np.asarray(r.history["V"])
    increases = (np.diff(V) > 1e-6 * np.abs(V[:-1])).sum()
    assert increases <= 10                      # only τ-adaptation blips
    gap_closed = (V[-1] - lasso.v_star) / (V[0] - lasso.v_star)
    assert gap_closed < 1e-3


def test_selection_rule_invariants(lasso):
    """Sᵏ is non-empty and contains the ρ-max block (Step S.3)."""
    E = jnp.asarray(np.random.default_rng(0).uniform(0, 1, 64),
                    jnp.float32)
    for rho in (0.1, 0.5, 1.0):
        mask = selection.greedy_mask(E, rho)
        assert float(mask.sum()) >= 1
        assert bool(mask[int(jnp.argmax(E))] == 1)
        # every selected block is within factor ρ of the max
        sel = np.asarray(mask) > 0
        assert (np.asarray(E)[sel] >= rho * float(E.max()) - 1e-7).all()
    assert float(selection.southwell_mask(E).sum()) == 1
    assert float(selection.topk_mask(E, 7).sum()) == 7


def test_stationarity_iff_fixed_point(lasso):
    """Prop. 3(b): x̂(x*) = x* exactly at stationary points."""
    r = flexa.solve(lasso, cfg=SolverConfig(max_iters=800, tol=1e-8))
    # at (near-)solution the best-response displacement is tiny
    assert float(r.state.stat) < 1e-4
    # at a random point it is large
    st0 = flexa.init_state(lasso, jnp.ones(lasso.n), SolverConfig())
    step = flexa.make_step(lasso, SolverConfig())
    _, info = step(st0)
    assert float(info["stat"]) > 1e-2


def test_tau_changes_are_finite(lasso):
    r = flexa.solve(lasso, cfg=SolverConfig(max_iters=500, tol=0))
    assert int(r.state.n_tau_changes) <= flexa.MAX_TAU_CHANGES


def test_linear_vs_exact_block_surrogates(lasso):
    """Both P_i choices converge; exact block (6) is at least as fast —
    the paper's reason for preferring it in the experiments."""
    r_ex = flexa.solve(lasso, cfg=SolverConfig(
        max_iters=300, tol=0, surrogate="exact_block"))
    r_li = flexa.solve(lasso, cfg=SolverConfig(
        max_iters=300, tol=0, surrogate="linear", tau0=0.0))
    assert rel_err(lasso, r_ex.history["V"][-1]) < 1e-3
    assert r_ex.history["V"][-1] <= r_li.history["V"][-1] * 1.5


def test_group_lasso_convergence():
    p = nesterov_group_instance(m=60, n_blocks=60, block_size=5,
                                nnz_frac=0.15, c=1.0, seed=1)
    r = flexa.solve(p, cfg=SolverConfig(max_iters=800, tol=1e-8))
    assert rel_err(p, r.history["V"][-1]) < 1e-3
    # group sparsity: off-support blocks have (near-)zero norm
    xb = np.asarray(r.x).reshape(60, 5)
    xsb = np.asarray(p.x_star).reshape(60, 5)
    off = np.linalg.norm(xsb, axis=1) == 0
    assert np.linalg.norm(xb[off], axis=1).max() < 2e-2


def test_inexact_subproblems_still_converge():
    """Theorem 1's εᵏ feature: inner prox-gradient solves on group blocks."""
    p = nesterov_group_instance(m=50, n_blocks=40, block_size=5,
                                nnz_frac=0.2, c=1.0, seed=2)
    cfg = SolverConfig(max_iters=800, tol=1e-8, surrogate="newton_cg",
                       inexact_alpha1=0.5)
    r = flexa.solve(p, cfg=cfg)
    assert rel_err(p, r.history["V"][-1]) < 5e-3


def test_sparse_logreg_stationarity():
    p = random_logreg_instance(m=120, n=200, nnz_frac=0.1, c=0.5, seed=0)
    r = flexa.solve(p, cfg=SolverConfig(max_iters=1500, tol=1e-7))
    assert float(p.stationarity(r.x)) < 5e-3
    # ℓ1 actually sparsifies
    assert (np.abs(np.asarray(r.x)) < 1e-6).mean() > 0.3


def test_svm_stationarity():
    p = random_svm_instance(m=100, n=150, nnz_frac=0.15, c=0.5, seed=0)
    r = flexa.solve(p, cfg=SolverConfig(max_iters=3000, tol=1e-7))
    assert float(p.stationarity(r.x)) < 5e-3


def test_solve_compiled_matches_python_loop(lasso):
    cfg = SolverConfig(max_iters=150, tol=1e-10)
    r1 = flexa.solve(lasso, cfg=cfg)
    r2 = flexa.solve_compiled(lasso, cfg=cfg)
    assert r1.iters == r2.iters
    np.testing.assert_allclose(np.asarray(r1.x), np.asarray(r2.x),
                               atol=1e-5)
