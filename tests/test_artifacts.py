"""Artifact-pipeline integrity: the committed dry-run/roofline results stay
consistent with the registry (guards against config drift)."""
import json
from pathlib import Path

import pytest

RESULTS = Path(__file__).resolve().parent.parent / "results"


@pytest.mark.skipif(not (RESULTS / "roofline.json").exists(),
                    reason="roofline artifacts not generated")
def test_roofline_covers_all_cells():
    rows = json.loads((RESULTS / "roofline.json").read_text())
    ok = [r for r in rows if r["status"] == "ok"]
    skipped = [r for r in rows if r["status"] == "skipped"]
    assert len(ok) == 32
    assert len(skipped) == 8
    for r in ok:
        assert r["dominant"] in ("compute", "memory", "collective")
        assert r["hlo_flops"] > 0
        assert 0 < r["useful_ratio"] < 1.5, (r["arch"], r["shape"])
        # prefill cells: no backward ⇒ MODEL/HLO ≈ 1 (methodology check)
        if r["shape"] == "prefill_32k":
            assert 0.8 < r["useful_ratio"] < 1.25, r["arch"]


@pytest.mark.skipif(not (RESULTS / "dryrun").exists(),
                    reason="dry-run artifacts not generated")
def test_dryrun_multipod_coverage_and_budget():
    from repro.config.base import SHAPES
    from repro.configs.registry import ARCHS, cell_applicable
    missing, over = [], []
    for arch, cfg in ARCHS.items():
        for sname, shape in SHAPES.items():
            if not cell_applicable(cfg, shape)[0]:
                continue
            for mesh in ("16x16", "2x16x16"):
                f = RESULTS / "dryrun" / f"{arch}__{sname}__{mesh}.json"
                if not f.exists():
                    missing.append(f.name)
                    continue
                rec = json.loads(f.read_text())
                assert rec["status"] == "ok", f.name
                live = rec["memory"].get("temp_size_in_bytes", 0) + \
                    rec["memory"].get("argument_size_in_bytes", 0)
                if live > 16e9:
                    over.append((f.name, live / 1e9))
    assert not missing, missing
    assert not over, over
