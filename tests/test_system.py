"""End-to-end behaviour tests for the paper's system.

Ties the layers together: the paper-faithful solver race reproduces the
paper's ranking at miniature scale, and the FLEXA optimizer trains a real
(reduced) transformer.
"""
import numpy as np

from repro.baselines import fista, grock
from repro.config.base import SolverConfig, TrainConfig
from repro.configs.registry import get_reduced
from repro.core import flexa
from repro.problems.lasso import nesterov_instance
from repro.train.loop import TrainLoop


def test_fig1_ranking_reproduces_miniature():
    """Paper Fig. 1 qualitative claims at miniature scale:
    FPA ≥ FISTA at matched iteration budgets; GRock(P) fragile on the
    lower-sparsity instance while FPA converges."""
    p = nesterov_instance(m=100, n=500, nnz_frac=0.1, c=1.0, seed=0)
    iters = 500
    r_fpa = flexa.solve(p, cfg=SolverConfig(max_iters=iters, tol=0))
    r_fis = fista.solve(p, max_iters=iters, tol=0)
    rel = lambda v: (v - p.v_star) / p.v_star
    assert rel(r_fpa.history["V"][-1]) < rel(r_fis.history["V"][-1])
    assert rel(r_fpa.history["V"][-1]) < 1e-4

    r_gr = grock.solve(p, P=32, max_iters=iters, tol=0)
    assert (not np.isfinite(r_gr.history["V"][-1])
            or rel(r_gr.history["V"][-1]) > rel(r_fpa.history["V"][-1]))


def test_flexa_trains_reduced_lm_better_than_chance():
    cfg = get_reduced("yi-6b")
    tcfg = TrainConfig(optimizer="flexa", flexa_tau0=2.0, steps=40,
                       log_every=1000)
    loop = TrainLoop(cfg, tcfg, batch=4, seq_len=64, mesh=None)
    loop.run()
    losses = [m["loss"] for m in loop.metrics_log]
    chance = np.log(cfg.vocab_size)
    assert losses[-1] < chance - 0.5          # clearly below uniform
    assert losses[-1] < losses[0]
