"""Property tests for the Step-S.3 selection rules (repro.core.selection).

The Theorem-1 convergence condition is that Sᵏ contains at least one block
with ``Eᵢ ≥ ρ·maxⱼ Eⱼ``.  The deterministic greedy-family rules (greedy,
southwell, topk, full) must satisfy it for every E; the arXiv:1407.4504
randomized rules (random, hybrid) are **exempt** — their convergence is
almost-sure (hybrid satisfies the condition relative to its sketch, which
is asserted instead) — and the essentially-cyclic rule is exempt via its
own guarantee (every block selected once per cycle, asserted too).

Properties run under hypothesis when the optional test extra is installed;
otherwise over a fixed grid of representative E vectors (same pattern as
``test_prox_properties``), so the suite is meaningful on a bare container.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional test extra
    HAVE_HYPOTHESIS = False

from repro.core import selection
from repro.config.base import SolverConfig

# Deterministic fallback E vectors: ties, near-ties, spikes, constants.
E_CASES = [
    [1.0, 1.0, 1.0, 1.0],                       # all tied
    [0.0, 0.0, 5.0, 0.0],                       # single spike
    [3.0, 3.0, 3.0, 0.1, 0.2],                  # tied max group
    list(np.linspace(0.01, 1.0, 32)),           # smooth ramp
    list(np.random.default_rng(0).uniform(0, 1, 64)),
    list(np.random.default_rng(1).exponential(1.0, 48)),
    [1e-6, 2e-6, 1.5e-6],                       # tiny scale
]
RHOS = (0.1, 0.5, 1.0)
SEEDS = (0, 1, 2)


def _es():
    if HAVE_HYPOTHESIS:
        return settings(max_examples=40, deadline=None)(given(
            st.lists(st.floats(0, 100, allow_nan=False), min_size=2,
                     max_size=64),
            st.sampled_from(RHOS), st.sampled_from(SEEDS)))
    return pytest.mark.parametrize(
        "vals,rho,seed",
        [(e, r, s) for e in E_CASES for r in RHOS for s in SEEDS[:1]])


def _theorem1_holds(E, mask, rho):
    """Sᵏ contains a block with Eᵢ ≥ ρ·max Eⱼ."""
    E, mask = np.asarray(E), np.asarray(mask)
    sel = mask > 0
    return sel.any() and (E[sel] >= rho * E.max() - 1e-7 * E.max()).any()


def _check_binary(mask, n):
    m = np.asarray(mask)
    assert m.shape == (n,)
    assert np.isin(m, (0.0, 1.0)).all()


@_es()
def test_deterministic_rules_satisfy_theorem1(vals, rho, seed):
    """greedy/southwell/topk/full all contain a ρ-max block for any E."""
    del seed
    E = jnp.asarray(vals, jnp.float32)
    n = E.shape[0]
    for mask in (selection.greedy_mask(E, rho),
                 selection.southwell_mask(E),
                 selection.topk_mask(E, max(1, n // 2)),
                 selection.full_mask(E)):
        _check_binary(mask, n)
        assert _theorem1_holds(E, mask, rho)
    # greedy additionally selects *exactly* the ρ-max set
    g = np.asarray(selection.greedy_mask(E, rho)) > 0
    assert (np.asarray(E)[g] >= rho * float(E.max()) - 1e-6).all()


@_es()
def test_topk_exact_count_under_ties(vals, rho, seed):
    """topk returns exactly k ones even when E has ties at the threshold."""
    del rho, seed
    E = jnp.asarray(vals, jnp.float32)
    n = E.shape[0]
    for k in (1, max(1, n // 3), n, n + 5):
        mask = selection.topk_mask(E, k)
        _check_binary(mask, n)
        assert int(np.asarray(mask).sum()) == min(k, n)
    # hard tie case: every entry equal
    tied = jnp.full((n,), 1.0, jnp.float32)
    for k in (1, max(1, n - 1)):
        assert int(np.asarray(selection.topk_mask(tied, k)).sum()) == k


@_es()
def test_random_mask_is_binary_and_nonempty(vals, rho, seed):
    """The random rule (Theorem-1 exempt: a.s. convergence per
    arXiv:1407.4504) still always returns a usable nonempty {0,1} mask."""
    del rho
    E = jnp.asarray(vals, jnp.float32)
    key = jax.random.PRNGKey(seed)
    for p in (0.01, 0.25, 0.9):
        mask = selection.random_mask(E, p, key)
        _check_binary(mask, E.shape[0])
        assert np.asarray(mask).sum() >= 1          # empty-draw fallback


@_es()
def test_hybrid_contains_sketch_argmax(vals, rho, seed):
    """hybrid ⊆ its sketch and satisfies the greedy condition *relative to
    the sketch* (contains the sketch argmax) — the rule's Theorem-1
    surrogate; globally it is random-rule exempt."""
    E = jnp.asarray(vals, jnp.float32)
    key = jax.random.PRNGKey(seed)
    mask = np.asarray(selection.hybrid_mask(E, rho, 0.5, key))
    # same key ⇒ the very sketch hybrid_mask drew internally
    sketch = np.asarray(selection.random_mask(E, 0.5, key))
    _check_binary(mask, E.shape[0])
    assert (mask <= sketch).all()                   # subset of the sketch
    En = np.asarray(E) * sketch
    if En.max() > 0:
        assert mask[int(En.argmax())] == 1          # sketch argmax kept
        assert (En[mask > 0] >= rho * En.max() - 1e-6 * En.max()).all()


@_es()
def test_cyclic_rule_covers_every_block_each_cycle(vals, rho, seed):
    """cyclic (Theorem-1 exempt: essentially-cyclic convergence): chunks
    are disjoint, balanced to within one block, and their union over one
    cycle is all of 𝒩."""
    del rho
    n = len(vals)
    chunks = min(4, n)
    key = jax.random.PRNGKey(seed)
    masks = [np.asarray(selection.cyclic_shuffle_mask(n, k, chunks, key))
             for k in range(chunks)]
    for m in masks:
        _check_binary(m, n)
    total = np.stack(masks).sum(axis=0)
    assert (total == 1).all()                       # disjoint AND covering
    sizes = [m.sum() for m in masks]
    assert max(sizes) - min(sizes) <= 1             # balanced round-robin
    # iteration k and k + chunks select the same chunk (a true cycle)
    np.testing.assert_array_equal(
        masks[0], np.asarray(selection.cyclic_shuffle_mask(
            n, chunks, chunks, key)))


def test_cyclic_clamps_when_chunks_exceed_blocks():
    """n_chunks > n_blocks must never produce an empty Sᵏ (which would
    burn iterations — x unchanged while γ decays): the cycle length is
    clamped to the block count."""
    key = jax.random.PRNGKey(0)
    n = 3
    for k in range(8):
        m = np.asarray(selection.cyclic_shuffle_mask(n, k, 10, key))
        assert m.sum() == 1                     # clamped to n chunks of 1
    union = sum(np.asarray(selection.cyclic_shuffle_mask(n, k, 10, key))
                for k in range(n))
    assert (union == 1).all()


def test_masks_shape_stable_under_vmap():
    """Every rule vmaps over a batch of E (and keys) to a (B, n) {0,1}
    mask — the property the batched multi-instance engine relies on."""
    B, n = 5, 33
    E = jnp.asarray(np.random.default_rng(3).uniform(0, 1, (B, n)),
                    jnp.float32)
    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(B))
    outs = {
        "greedy": jax.vmap(lambda e: selection.greedy_mask(e, 0.5))(E),
        "southwell": jax.vmap(selection.southwell_mask)(E),
        "topk": jax.vmap(lambda e: selection.topk_mask(e, 7))(E),
        "full": jax.vmap(selection.full_mask)(E),
        "random": jax.vmap(
            lambda e, k: selection.random_mask(e, 0.3, k))(E, keys),
        "hybrid": jax.vmap(
            lambda e, k: selection.hybrid_mask(e, 0.5, 0.3, k))(E, keys),
        "cyclic": jax.vmap(
            lambda k: selection.cyclic_shuffle_mask(
                n, k, 4, jax.random.PRNGKey(0)))(jnp.arange(B)),
    }
    for name, m in outs.items():
        m = np.asarray(m)
        assert m.shape == (B, n), name
        assert np.isin(m, (0.0, 1.0)).all(), name
        assert (m.sum(axis=-1) >= 1).all(), name
    # per-instance keys ⇒ not all random rows identical
    assert not (np.asarray(outs["random"]) ==
                np.asarray(outs["random"])[0]).all()


def test_random_mask_hits_requested_density():
    """E[|Sᵏ|]/N ≈ p (sanity on the sketch probability knob)."""
    E = jnp.ones((200,), jnp.float32)
    fracs = [float(np.asarray(
        selection.random_mask(E, 0.25, jax.random.PRNGKey(s))).mean())
        for s in range(30)]
    assert abs(np.mean(fracs) - 0.25) < 0.05


def test_make_mask_dispatch_and_unknown_rule():
    E = jnp.asarray([0.1, 0.9, 0.5], jnp.float32)
    key = jax.random.PRNGKey(0)
    for rule in ("greedy", "full", "southwell", "topk", "random",
                 "hybrid", "cyclic"):
        cfg = SolverConfig(selection=rule, sel_k=2)
        m = selection.make_mask(E, cfg, key, 0)
        _check_binary(m, 3)
    # back-compat: jacobi flag overrides to the full rule
    m = selection.make_mask(E, SolverConfig(selection="greedy", jacobi=True),
                            key, 0)
    assert np.asarray(m).sum() == 3
    with pytest.raises(ValueError, match="unknown selection rule"):
        selection.make_mask(E, SolverConfig(selection="best"), key, 0)
