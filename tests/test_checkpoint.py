"""Checkpointing: atomicity, retention, async, elastic restore."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer


def tree():
    return {"w": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.ones((5,)), "s": jnp.asarray(3)}}


def test_roundtrip(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    t = tree()
    ck.save(7, t)
    restored, step = ck.restore(t)
    assert step == 7
    for a, b in zip(jax.tree_util.tree_leaves(restored),
                    jax.tree_util.tree_leaves(t)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_pointer_and_retention(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, tree())
    assert ck.latest_step() == 4
    kept = sorted(p.name for p in tmp_path.glob("step_????????"))
    assert kept == ["step_00000003", "step_00000004"]


def test_async_save(tmp_path):
    ck = Checkpointer(tmp_path, keep=3)
    ck.save_async(5, tree())
    ck.wait()
    assert ck.latest_step() == 5
    restored, _ = ck.restore(tree())
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree()["w"]))


def test_partial_write_is_invisible(tmp_path):
    """A crash mid-write (simulated .tmp dir) must not corrupt restore."""
    ck = Checkpointer(tmp_path, keep=3)
    ck.save(1, tree())
    # simulate a torn write
    (tmp_path / "step_00000002.tmp").mkdir()
    (tmp_path / "step_00000002.tmp" / "leaf_00000.npy").write_bytes(b"junk")
    assert ck.latest_step() == 1
    restored, step = ck.restore(tree())
    assert step == 1


def test_stale_pointer_falls_back(tmp_path):
    ck = Checkpointer(tmp_path, keep=3)
    ck.save(1, tree())
    ck.save(2, tree())
    (tmp_path / "LATEST").write_text("step_00000099")  # corrupt pointer
    assert ck.latest_step() == 2


ELASTIC_SRC = textwrap.dedent("""
    import os, sys, json
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.checkpoint import Checkpointer

    ckdir = sys.argv[1]
    mesh = jax.make_mesh((8,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data", None)),
          "nested": {"b": NamedSharding(mesh, P()),
                     "s": NamedSharding(mesh, P())}}
    like = {"w": jnp.zeros((16, 4)), "nested": {"b": jnp.zeros((5,)),
            "s": jnp.asarray(0)}}
    ck = Checkpointer(ckdir)
    restored, step = ck.restore(like, shardings=sh)
    print(json.dumps({
        "step": step,
        "sum": float(jnp.sum(restored["w"])),
        "nshards": len(restored["w"].sharding.device_set),
    }))
""")


def test_elastic_restore_onto_different_topology(tmp_path):
    """Write on 1 device, restore 8-way sharded in a subprocess."""
    t = {"w": jnp.arange(64.0).reshape(16, 4),
         "nested": {"b": jnp.ones((5,)), "s": jnp.asarray(3)}}
    Checkpointer(tmp_path).save(11, t)
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", ELASTIC_SRC, str(tmp_path)],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(__file__)))
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["step"] == 11
    assert rec["sum"] == float(np.arange(64.0).sum())
    assert rec["nshards"] == 8
