"""Distribution-correctness tests.

The heavy multi-device checks run in a subprocess with 8 forced host
devices (so the main pytest process keeps the real 1-device topology).
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax.numpy as jnp
import pytest

from repro.config.base import SolverConfig
from repro.core import flexa, pflexa
from repro.problems.lasso import nesterov_instance


def test_pflexa_matches_serial_single_device():
    p = nesterov_instance(m=60, n=320, nnz_frac=0.1, c=1.0, seed=1)
    cfg = SolverConfig(max_iters=150, tol=1e-12)
    r1 = flexa.solve(p, cfg=cfg)
    r2 = pflexa.solve(p.data["A"], p.data["b"], 1.0, cfg=cfg)
    assert np.abs(np.asarray(r1.x) - np.asarray(r2.x)).max() < 1e-3


@pytest.mark.parametrize("rule", ["random", "hybrid", "cyclic"])
def test_pflexa_randomized_selection_converges(rule):
    """The sharded random/hybrid/cyclic S.3 path (per-shard fold_in keys,
    psum empty-draw fallback, pmax sketch max) converges to the planted
    optimum on a 1-device mesh — fast coverage of the branch the 8-way
    slow test does not exercise."""
    p = nesterov_instance(m=40, n=160, nnz_frac=0.1, c=1.0, seed=0)
    cfg = SolverConfig(max_iters=2000, tol=1e-6, selection=rule,
                       sel_p=0.25, seed=2)
    r = pflexa.solve(p.data["A"], p.data["b"], 1.0, cfg=cfg)
    rel = (r.history["V"][-1] - p.v_star) / p.v_star
    assert r.converged and rel < 1e-5, (rule, rel)
    # seed-deterministic
    r2 = pflexa.solve(p.data["A"], p.data["b"], 1.0, cfg=cfg)
    np.testing.assert_array_equal(np.asarray(r.x), np.asarray(r2.x))


def test_pflexa_rejects_unsupported_selection():
    p = nesterov_instance(m=20, n=64, nnz_frac=0.1, c=1.0, seed=0)
    with pytest.raises(ValueError, match="pflexa supports"):
        pflexa.solve(p.data["A"], p.data["b"], 1.0,
                     cfg=SolverConfig(selection="topk"))


SUBPROCESS_SRC = textwrap.dedent("""
    import os, json
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    from repro.config.base import SolverConfig
    from repro.core import pflexa
    from repro.problems.lasso import nesterov_instance

    p = nesterov_instance(m=60, n=320, nnz_frac=0.1, c=1.0, seed=1)
    cfg = SolverConfig(max_iters=150, tol=1e-12)
    r = pflexa.solve(p.data["A"], p.data["b"], 1.0, cfg=cfg)
    print(json.dumps({
        "n_devices": len(jax.devices()),
        "V": r.history["V"][-1],
        "x_head": np.asarray(r.x)[:8].tolist(),
    }))
""")


@pytest.mark.slow
def test_pflexa_8way_matches_serial():
    """The paper's MPI layout on 8 shards == the serial algorithm."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SUBPROCESS_SRC],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["n_devices"] == 8

    p = nesterov_instance(m=60, n=320, nnz_frac=0.1, c=1.0, seed=1)
    r1 = flexa.solve(p, cfg=SolverConfig(max_iters=150, tol=1e-12))
    assert abs(rec["V"] - r1.history["V"][-1]) < 1e-2
    np.testing.assert_allclose(np.asarray(r1.x)[:8],
                               np.asarray(rec["x_head"]), atol=1e-3)


def test_gradient_compression_preserves_convergence():
    """Error-feedback top-k / int8 on a strongly-convex quadratic: the
    compressed gradient iteration still reaches the optimum."""
    from repro.distributed import compression as C
    rng = np.random.default_rng(0)
    A = rng.standard_normal((40, 20))
    H = A.T @ A + np.eye(20)
    b = rng.standard_normal(20)
    x_star = np.linalg.solve(H, b)

    for kind in ("topk", "int8"):
        x = {"w": jnp.zeros(20)}
        state = C.init_state(x)
        lr = 0.5 / np.linalg.eigvalsh(H).max()
        for _ in range(500):
            g = {"w": jnp.asarray(H @ np.asarray(x["w"]) - b)}
            cg, state = C.compress(g, state, kind=kind, topk_frac=0.25)
            x = {"w": x["w"] - lr * cg["w"]}
        err = np.abs(np.asarray(x["w"]) - x_star).max()
        assert err < 1e-2, (kind, err)

    # wire accounting: topk/int8 strictly cheaper than dense fp32
    g = {"w": jnp.zeros(1000)}
    assert C.wire_bytes(g, "topk", 0.1) < C.wire_bytes(g, "none")
    assert C.wire_bytes(g, "int8") < C.wire_bytes(g, "none")


def test_sharding_rules_cover_all_archs():
    """spec_for_param yields a valid spec for every param of every arch."""
    import jax
    from repro.configs.registry import ARCHS, get_reduced
    from repro.distributed.sharding import spec_for_param, Dist
    from repro.launch.mesh import make_host_mesh
    from repro.models import transformer as T
    from functools import partial

    mesh = make_host_mesh()  # 1 device: (1, 1) data×model
    dist = Dist(mesh=mesh)
    for arch in ARCHS:
        cfg = get_reduced(arch)
        pshape = jax.eval_shape(partial(T.init_params, cfg),
                                jax.random.PRNGKey(0))
        flat, _ = jax.tree_util.tree_flatten_with_path(pshape)
        for path, leaf in flat:
            name = "/".join(str(getattr(p, "key", p)) for p in path)
            spec = spec_for_param(name, leaf.shape, dist, cfg)
            assert len(spec) <= len(leaf.shape), (arch, name)
