# NOTE: no XLA_FLAGS here on purpose — tests and benches must see the real
# single CPU device; only launch/dryrun.py forces the 512-device topology.
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
